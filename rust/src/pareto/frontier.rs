//! The analytic time–energy Pareto frontier of one scenario.
//!
//! `T_final` is unimodal with its minimum at `T_Time_opt` and `E_final`
//! is unimodal with its minimum at `T_Energy_opt` (§3). On the period
//! segment between the two optima the objectives are strictly
//! conflicting — moving toward one optimum walks away from the other —
//! so **every** period in `[min(T_T, T_E), max(T_T, T_E)]` is
//! Pareto-optimal and the segment *is* the exact frontier. [`Frontier`]
//! samples it densely (endpoints pinned to the optima bit-for-bit),
//! filters numerically dominated samples, and exposes the derived
//! quantities downstream consumers need: normalised coordinates,
//! hypervolume, and knee points ([`super::knee`]).
//!
//! The unimodal/conflicting structure holds for **both** objective
//! backends ([`Backend::FirstOrder`] and [`Backend::Exact`]), so the
//! whole construction is generic over the [`Backend`]: the exact
//! backend moves the optima (and with them the knee) by 5–40% at small
//! `μ` while the geometry of the frontier machinery is unchanged.

use crate::model::backend::Backend;
use crate::model::params::{ModelError, Scenario};
use crate::util::pool::ThreadPool;

use super::knee::{knee, Knee, KneeMethod};

/// One point of the frontier: a checkpointing period and the two
/// objective values the selected backend assigns to it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontierPoint {
    /// Checkpointing period `T` (minutes).
    pub period: f64,
    /// Expected makespan `T_final(T)` (minutes).
    pub time: f64,
    /// Expected energy `E_final(T)` (mW·min).
    pub energy: f64,
}

impl FrontierPoint {
    /// Pareto dominance: at least as good in both objectives, strictly
    /// better in one.
    pub fn dominates(&self, other: &FrontierPoint) -> bool {
        self.time <= other.time
            && self.energy <= other.energy
            && (self.time < other.time || self.energy < other.energy)
    }
}

/// A sampled exact frontier. Points are sorted by makespan ascending
/// (equivalently energy descending): the first point is the AlgoT
/// endpoint, the last the AlgoE endpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Frontier {
    pub scenario: Scenario,
    /// The objective model the points were evaluated under.
    pub backend: Backend,
    /// Clamped `T_Time_opt` — the first point's period.
    pub t_time_opt: f64,
    /// Clamped `T_Energy_opt` — the last point's period.
    pub t_energy_opt: f64,
    points: Vec<FrontierPoint>,
}

impl Frontier {
    /// Sample the frontier with `n >= 2` periods spaced uniformly
    /// between the two optima of `backend`'s objectives (endpoints
    /// exact). Errors when the scenario has no feasible period at all
    /// (the same gate under every backend; see
    /// [`Backend::t_time_opt`]).
    ///
    /// Sampling fans out on the process-wide [`ThreadPool`]: each point
    /// is a pure function of `(scenario, i, n, backend)` and
    /// [`ThreadPool::map`] scatters results by index, so the sampled
    /// vector is bit-identical at any thread count (nested calls from
    /// inside pool workers degrade to inline evaluation).
    pub fn compute(s: &Scenario, n: usize, backend: Backend) -> Result<Frontier, ModelError> {
        Self::compute_on(ThreadPool::global(), s, n, backend)
    }

    /// [`Self::compute`] on a caller-supplied pool (benches pin thread
    /// counts with this; the global-pool path is the serving default).
    pub fn compute_on(
        pool: &ThreadPool,
        s: &Scenario,
        n: usize,
        backend: Backend,
    ) -> Result<Frontier, ModelError> {
        assert!(n >= 2, "need at least the two endpoint samples, got {n}");
        let _span =
            crate::telemetry::Span::start(&crate::telemetry::registry::metrics::FRONTIER_SOLVE_NS);
        let tt = backend.t_time_opt(s)?;
        let te = backend.t_energy_opt(s)?;
        let (lo, hi) = if tt <= te { (tt, te) } else { (te, tt) };

        let sampled = if hi - lo <= 0.0 {
            // Degenerate trade-off: both optima clamp to the same period
            // (e.g. the Fig. 3 breakdown tail). One point, zero spread.
            vec![point_at(s, lo, backend)]
        } else {
            pool.map(n, |i| point_at(s, sample_period(lo, hi, i, n), backend))
        };
        Ok(Frontier {
            scenario: *s,
            backend,
            t_time_opt: tt,
            t_energy_opt: te,
            points: filter_dominated(sampled),
        })
    }

    /// Serial reference implementation of [`Self::compute`] — the
    /// pre-parallel sampling loop, kept as the bit-identity oracle for
    /// the zero-perturbation suite. Not part of the public API.
    #[doc(hidden)]
    pub fn compute_reference(
        s: &Scenario,
        n: usize,
        backend: Backend,
    ) -> Result<Frontier, ModelError> {
        assert!(n >= 2, "need at least the two endpoint samples, got {n}");
        let tt = backend.t_time_opt(s)?;
        let te = backend.t_energy_opt(s)?;
        let (lo, hi) = if tt <= te { (tt, te) } else { (te, tt) };

        let mut sampled = Vec::with_capacity(n);
        if hi - lo <= 0.0 {
            sampled.push(point_at(s, lo, backend));
        } else {
            for i in 0..n {
                sampled.push(point_at(s, sample_period(lo, hi, i, n), backend));
            }
        }
        Ok(Frontier {
            scenario: *s,
            backend,
            t_time_opt: tt,
            t_energy_opt: te,
            points: filter_dominated(sampled),
        })
    }

    /// The non-dominated points, sorted by makespan ascending.
    pub fn points(&self) -> &[FrontierPoint] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The AlgoT endpoint (minimum makespan).
    pub fn time_opt_point(&self) -> &FrontierPoint {
        self.points.first().expect("frontier has at least one point")
    }

    /// The AlgoE endpoint (minimum energy).
    pub fn energy_opt_point(&self) -> &FrontierPoint {
        self.points.last().expect("frontier has at least one point")
    }

    /// `(time, energy)` mapped to `[0, 1]²` over the frontier's own
    /// extremes: the AlgoT endpoint lands on `(0, 1)`, the AlgoE
    /// endpoint on `(1, 0)`. Empty when the frontier is degenerate
    /// (fewer than two points or zero spread in either objective).
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        if self.points.len() < 2 {
            return Vec::new();
        }
        let t_min = self.time_opt_point().time;
        let t_max = self.energy_opt_point().time;
        let e_min = self.energy_opt_point().energy;
        let e_max = self.time_opt_point().energy;
        let (t_span, e_span) = (t_max - t_min, e_max - e_min);
        if t_span <= 0.0 || e_span <= 0.0 {
            return Vec::new();
        }
        self.points
            .iter()
            .map(|p| ((p.time - t_min) / t_span, (p.energy - e_min) / e_span))
            .collect()
    }

    /// Normalised hypervolume dominated by the frontier w.r.t. the
    /// reference point `(1, 1)` in normalised coordinates. `0` for a
    /// degenerate frontier; `0.5` for a straight-line trade-off; →`1`
    /// for a sharply kneed one.
    pub fn hypervolume(&self) -> f64 {
        let norm = self.normalized();
        if norm.len() < 2 {
            return 0.0;
        }
        // Points are sorted by time ascending with energy strictly
        // decreasing, so each point's dominated strip spans to the next
        // point's time coordinate.
        let mut hv = 0.0;
        for (i, &(t, e)) in norm.iter().enumerate() {
            let t_next = if i + 1 < norm.len() { norm[i + 1].0 } else { 1.0 };
            hv += (t_next - t) * (1.0 - e);
        }
        hv
    }

    /// Knee point under the given detection method (`None` when the
    /// frontier has no interior point).
    pub fn knee(&self, method: KneeMethod) -> Option<Knee> {
        knee(self, method)
    }

    /// Consume the frontier, keeping only the point list.
    pub fn into_points(self) -> Vec<FrontierPoint> {
        self.points
    }
}

/// The `i`-th of `n` sample periods on `[lo, hi]`: endpoints pinned to
/// the optima exactly, interior points uniform in the period. One
/// shared formula so the pooled and serial sampling paths cannot drift.
fn sample_period(lo: f64, hi: f64, i: usize, n: usize) -> f64 {
    if i == 0 {
        lo
    } else if i == n - 1 {
        hi
    } else {
        lo + (hi - lo) * i as f64 / (n - 1) as f64
    }
}

fn point_at(s: &Scenario, period: f64, backend: Backend) -> FrontierPoint {
    // One evaluation for both objectives: under the exact backend this
    // computes the renewal breakdown once per sample instead of twice.
    let (time, energy) = backend.objectives(s, period);
    FrontierPoint { period, time, energy }
}

/// Drop dominated points: sort by `(time, energy)` ascending and keep
/// every point that strictly improves the best energy seen so far. On a
/// cleanly sampled frontier this is the identity; it exists to absorb
/// flat clamped stretches and last-ulp ties.
pub fn filter_dominated(mut points: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
    points.sort_by(|a, b| {
        (a.time, a.energy).partial_cmp(&(b.time, b.energy)).expect("finite objectives")
    });
    let mut kept: Vec<FrontierPoint> = Vec::with_capacity(points.len());
    let mut best_energy = f64::INFINITY;
    for p in points {
        if p.energy < best_energy {
            best_energy = p.energy;
            kept.push(p);
        }
    }
    kept
}

/// Compact, cacheable frontier record — what a
/// [`CellJob::Frontier`](crate::sweep::CellJob) grid cell computes and
/// the memo cache stores. Unlike the pre-backend revision, `compute`
/// returns `Result` (matching [`Frontier::compute`]) so figure and CLI
/// callers can surface the domain error instead of silently dropping
/// the row; grid cells map the error to `None` at the cell boundary
/// (their clamp regime is unchanged).
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierSummary {
    pub backend: Backend,
    pub t_time_opt: f64,
    pub t_energy_opt: f64,
    pub hypervolume: f64,
    pub knee_chord: Option<Knee>,
    pub knee_curvature: Option<Knee>,
    pub points: Vec<FrontierPoint>,
}

impl FrontierSummary {
    pub fn compute(
        s: &Scenario,
        points: usize,
        backend: Backend,
    ) -> Result<FrontierSummary, ModelError> {
        let f = Frontier::compute(s, points.max(2), backend)?;
        Ok(FrontierSummary {
            backend,
            t_time_opt: f.t_time_opt,
            t_energy_opt: f.t_energy_opt,
            hypervolume: f.hypervolume(),
            knee_chord: f.knee(KneeMethod::MaxDistanceToChord),
            knee_curvature: f.knee(KneeMethod::MaxCurvature),
            points: f.into_points(),
        })
    }

    /// Extra time paid at `point`, in percent of the AlgoT endpoint's
    /// makespan.
    pub fn time_overhead_pct(&self, point: &FrontierPoint) -> f64 {
        let t0 = self.points.first().map(|p| p.time).unwrap_or(f64::NAN);
        (point.time / t0 - 1.0) * 100.0
    }

    /// Energy saved at `point`, in percent of the AlgoT endpoint's
    /// energy.
    pub fn energy_gain_pct(&self, point: &FrontierPoint) -> f64 {
        let e0 = self.points.first().map(|p| p.energy).unwrap_or(f64::NAN);
        (1.0 - point.energy / e0) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::model::exact::RecoveryModel;
    use crate::model::{e_final, t_final};
    use crate::util::stats::rel_err;

    #[test]
    fn endpoints_are_the_optima_bit_for_bit() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        assert_eq!(f.time_opt_point().period.to_bits(), f.t_time_opt.to_bits());
        assert_eq!(f.energy_opt_point().period.to_bits(), f.t_energy_opt.to_bits());
        assert_eq!(
            f.time_opt_point().time.to_bits(),
            t_final(&s, f.t_time_opt).to_bits()
        );
        assert_eq!(
            f.energy_opt_point().energy.to_bits(),
            e_final(&s, f.t_energy_opt).to_bits()
        );
    }

    #[test]
    fn exact_endpoints_are_the_exact_optima() {
        let s = fig1_scenario(120.0, 5.5);
        let b = Backend::Exact(RecoveryModel::Ideal);
        let f = Frontier::compute(&s, 33, b).unwrap();
        assert_eq!(f.backend, b);
        assert_eq!(f.time_opt_point().period.to_bits(), b.t_time_opt(&s).unwrap().to_bits());
        assert_eq!(
            f.energy_opt_point().period.to_bits(),
            b.t_energy_opt(&s).unwrap().to_bits()
        );
        assert_eq!(
            f.time_opt_point().time.to_bits(),
            b.t_final(&s, f.t_time_opt).to_bits()
        );
    }

    #[test]
    fn no_point_dominates_another() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 65, Backend::FirstOrder).unwrap();
        let pts = f.points();
        assert!(pts.len() >= 60, "kept {} of 65", pts.len());
        for (i, p) in pts.iter().enumerate() {
            for (j, q) in pts.iter().enumerate() {
                if i != j {
                    assert!(!p.dominates(q), "{p:?} dominates {q:?}");
                }
            }
        }
    }

    #[test]
    fn monotone_trade_off_along_the_frontier_under_both_backends() {
        let s = fig1_scenario(120.0, 7.0);
        for backend in [Backend::FirstOrder, Backend::Exact(RecoveryModel::Restarting)] {
            let f = Frontier::compute(&s, 40, backend).unwrap();
            for w in f.points().windows(2) {
                assert!(w[1].time > w[0].time, "{}", backend.name());
                assert!(w[1].energy < w[0].energy, "{}", backend.name());
                assert!(w[1].period > w[0].period, "{}", backend.name());
            }
        }
    }

    #[test]
    fn normalized_hits_the_unit_corners() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 17, Backend::FirstOrder).unwrap();
        let n = f.normalized();
        assert_eq!(n.len(), f.len());
        assert!((n[0].0 - 0.0).abs() < 1e-12 && (n[0].1 - 1.0).abs() < 1e-12);
        let last = n.last().unwrap();
        assert!((last.0 - 1.0).abs() < 1e-12 && (last.1 - 0.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_in_unit_band_and_convex_beats_line() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 65, Backend::FirstOrder).unwrap();
        let hv = f.hypervolume();
        // The paper's trade-off curve bows below the chord (diminishing
        // returns), so the dominated volume exceeds the triangle's 0.5.
        assert!(hv > 0.5 && hv < 1.0, "hv={hv}");
    }

    #[test]
    fn hypervolume_of_straight_line_is_half() {
        // Synthetic straight frontier through filter_dominated + a fake
        // Frontier: easiest to assert via the formula on a hand-made set.
        let s = fig1_scenario(300.0, 5.5);
        let mut f = Frontier::compute(&s, 2, Backend::FirstOrder).unwrap();
        let (t0, e0) = (f.points[0].time, f.points[0].energy);
        let (t1, e1) = (f.points[1].time, f.points[1].energy);
        let n = 101;
        f.points = (0..n)
            .map(|i| {
                let w = i as f64 / (n - 1) as f64;
                FrontierPoint {
                    period: 0.0,
                    time: t0 + (t1 - t0) * w,
                    energy: e0 + (e1 - e0) * w,
                }
            })
            .collect();
        assert!((f.hypervolume() - 0.5).abs() < 0.02, "hv={}", f.hypervolume());
    }

    #[test]
    fn more_points_refine_not_change_the_span() {
        let s = fig1_scenario(300.0, 7.0);
        let coarse = Frontier::compute(&s, 9, Backend::FirstOrder).unwrap();
        let fine = Frontier::compute(&s, 129, Backend::FirstOrder).unwrap();
        assert!(rel_err(coarse.t_time_opt, fine.t_time_opt) < 1e-15);
        assert!(rel_err(coarse.t_energy_opt, fine.t_energy_opt) < 1e-15);
        // Hypervolume converges: refinement moves it only slightly.
        assert!((coarse.hypervolume() - fine.hypervolume()).abs() < 0.05);
    }

    #[test]
    fn degenerate_scenario_collapses_to_one_point() {
        // Fully-overlapped checkpoints (ω = 1) with free I/O power
        // (β = 0): both makespan and energy strictly grow with the
        // period, so AlgoT and AlgoE both clamp to T = C and the
        // trade-off vanishes.
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 1.0).unwrap();
        let power = crate::model::PowerParams::from_ratios(1.0, 0.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 300.0, 1e4).unwrap();
        let f = Frontier::compute(&s, 16, Backend::FirstOrder).unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f.hypervolume(), 0.0);
        assert!(f.knee(KneeMethod::MaxDistanceToChord).is_none());
        assert!(f.normalized().is_empty());
    }

    #[test]
    fn filter_drops_dominated_and_keeps_order() {
        let mk = |t: f64, e: f64| FrontierPoint { period: 0.0, time: t, energy: e };
        let kept = filter_dominated(vec![
            mk(3.0, 1.0),
            mk(1.0, 3.0),
            mk(2.0, 2.0),
            mk(2.5, 2.5), // dominated by (2, 2)
            mk(1.0, 4.0), // dominated by (1, 3)
        ]);
        assert_eq!(kept, vec![mk(1.0, 3.0), mk(2.0, 2.0), mk(3.0, 1.0)]);
    }

    #[test]
    fn summary_matches_frontier() {
        let s = fig1_scenario(300.0, 5.5);
        let f = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        let sum = FrontierSummary::compute(&s, 33, Backend::FirstOrder).unwrap();
        assert_eq!(sum.backend, Backend::FirstOrder);
        assert_eq!(sum.points, f.points().to_vec());
        assert_eq!(sum.hypervolume.to_bits(), f.hypervolume().to_bits());
        // Percent helpers anchor on the AlgoT endpoint.
        assert_eq!(sum.time_overhead_pct(&sum.points[0]), 0.0);
        assert_eq!(sum.energy_gain_pct(&sum.points[0]), 0.0);
        let last = *sum.points.last().unwrap();
        assert!(sum.time_overhead_pct(&last) > 0.0);
        assert!(sum.energy_gain_pct(&last) > 0.0);
    }

    #[test]
    fn summary_surfaces_the_domain_error() {
        // C >= 2*mu*b: no feasible period. The summary now reports WHY
        // (OutOfDomain) instead of a bare None.
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        for backend in [Backend::FirstOrder, Backend::Exact(RecoveryModel::Ideal)] {
            match FrontierSummary::compute(&s, 9, backend) {
                Err(ModelError::OutOfDomain(_)) => {}
                other => panic!("{}: expected OutOfDomain, got {other:?}", backend.name()),
            }
        }
    }

    #[test]
    fn pooled_sampling_matches_the_serial_reference_bit_for_bit() {
        let s = fig1_scenario(120.0, 5.5);
        for backend in [Backend::FirstOrder, Backend::Exact(RecoveryModel::Ideal)] {
            let reference = Frontier::compute_reference(&s, 65, backend).unwrap();
            for workers in [0, 3, 7] {
                let pool = ThreadPool::new(workers);
                let pooled = Frontier::compute_on(&pool, &s, 65, backend).unwrap();
                assert_eq!(pooled, reference, "{} workers under {}", workers, backend.name());
            }
            assert_eq!(Frontier::compute(&s, 65, backend).unwrap(), reference);
        }
    }

    #[test]
    fn exact_frontier_shifts_toward_longer_periods_at_small_mu() {
        // The exact objectives are better balanced by longer periods in
        // the frequent-failure regime (the knee-drift headline).
        let s = fig1_scenario(60.0, 5.5);
        let fo = Frontier::compute(&s, 33, Backend::FirstOrder).unwrap();
        let ex = Frontier::compute(&s, 33, Backend::Exact(RecoveryModel::Ideal)).unwrap();
        assert!(ex.t_time_opt > fo.t_time_opt * 1.1, "{} vs {}", ex.t_time_opt, fo.t_time_opt);
        assert!(
            ex.t_energy_opt > fo.t_energy_opt * 1.1,
            "{} vs {}",
            ex.t_energy_opt,
            fo.t_energy_opt
        );
    }
}
