//! Time–energy Pareto frontier subsystem.
//!
//! The paper's headline contribution is the *range* of trade-offs
//! between the time-optimal and energy-optimal periods (§5); this
//! module turns that range into a first-class artifact. On the period
//! segment between `T_Time_opt` and `T_Energy_opt` the two objectives
//! are strictly conflicting (each is unimodal with its argmin at its
//! own endpoint), so the segment **is** the exact Pareto frontier —
//! no multi-objective search required, just the closed forms of
//! [`crate::model`].
//!
//! * [`frontier`] — dense frontier sampling between the optima
//!   (endpoints pinned bit-for-bit), dominated-point filtering,
//!   normalised coordinates and hypervolume.
//! * [`knee`] — knee-point detection (max distance to chord, max
//!   discrete curvature): where the trade-off stops paying.
//! * [`epsilon`] — ε-constraint solves ("minimise energy subject to a
//!   time overhead ≤ x%", and the transpose), exact by bisection along
//!   the frontier.
//! * [`validate`] — Monte-Carlo cross-check of the analytic frontier
//!   through seeded grid-engine sim cells, with the truncation-aware
//!   confidence bands `tests/sim_vs_model.rs` established.
//! * [`family`] — frontiers over whole scenario families
//!   ([`crate::config::presets::tradeoff_presets`], power-ratio
//!   sweeps), evaluated as [`CellJob::Frontier`](crate::sweep::CellJob)
//!   cells on the persistent pool with process-wide memoisation.
//! * [`online`] — frontier-derived periods for the *online* policies
//!   (knee, ε-constraint budgets) behind a quantised-key memo, so the
//!   adaptive controller's per-event re-reads stay cheap and
//!   deterministic.
//!
//! Consumers: `figures::frontier` (per-scenario frontier + knee
//! tables), the CLI `pareto` subcommand (tables + JSON artifact +
//! optional simulation), `coordinator::policy` (the knee/budget period
//! policies), and `examples/exascale_study`.

pub mod epsilon;
pub mod family;
pub mod frontier;
pub mod knee;
pub mod online;
pub mod validate;

pub use epsilon::{min_energy_with_time_overhead, min_time_with_energy_overhead, EpsSolution};
pub use family::{family_frontiers, FamilyFrontier};
pub use frontier::{Frontier, FrontierPoint, FrontierSummary};
pub use knee::{Knee, KneeMethod};
pub use validate::{validate, FrontierValidation, ValidatedPoint};
