//! Time–energy Pareto frontier subsystem.
//!
//! The paper's headline contribution is the *range* of trade-offs
//! between the time-optimal and energy-optimal periods (§5); this
//! module turns that range into a first-class artifact. On the period
//! segment between `T_Time_opt` and `T_Energy_opt` the two objectives
//! are strictly conflicting (each is unimodal with its argmin at its
//! own endpoint), so the segment **is** the exact Pareto frontier —
//! no multi-objective search required, just the objectives of
//! [`crate::model`].
//!
//! # Backend selection
//!
//! The whole stack is generic over the objective-model
//! [`Backend`](crate::model::Backend): `Backend::FirstOrder` evaluates
//! the paper's closed forms (the default, and bit-identical to the
//! pre-backend behaviour), `Backend::Exact(RecoveryModel)` the exact
//! renewal expectations of [`crate::model::exact`] with memoised
//! numeric optima. The unimodal/conflicting structure every module
//! below relies on holds under both, so frontiers, knees, ε-solves,
//! validation, families and the online policies all take the backend as
//! a parameter (CLI: `--model first-order|exact|exact:ideal|
//! exact:restarting`). Exact matters in the frequent-failure (small-μ)
//! regime, where the first-order knee sits 6–44% below the exact one —
//! `figures::knee_drift` tabulates the drift and EXPERIMENTS.md records
//! the headlines; at large μ the backends agree to well under a
//! percent.
//!
//! * [`frontier`] — dense frontier sampling between the optima
//!   (endpoints pinned bit-for-bit), dominated-point filtering,
//!   normalised coordinates and hypervolume.
//! * [`knee`] — knee-point detection (max distance to chord, max
//!   discrete curvature): where the trade-off stops paying.
//! * [`epsilon`] — ε-constraint solves ("minimise energy subject to a
//!   time overhead ≤ x%", and the transpose), exact by bisection along
//!   the frontier.
//! * [`validate`] — Monte-Carlo cross-check of the analytic frontier
//!   through seeded grid-engine sim cells, with the truncation-aware
//!   confidence bands `tests/sim_vs_model.rs` established.
//! * [`family`] — frontiers over whole scenario families
//!   ([`crate::config::presets::tradeoff_presets`], power-ratio
//!   sweeps), evaluated as [`CellJob::Frontier`](crate::sweep::CellJob)
//!   cells on the persistent pool with process-wide memoisation.
//! * [`online`] — frontier-derived periods for the *online* policies
//!   (knee, ε-constraint budgets) behind a quantised-key memo (the
//!   backend is part of the key), so the adaptive controller's
//!   per-event re-reads stay cheap and deterministic.
//!
//! Consumers: `figures::frontier` (per-scenario frontier + knee
//! tables), the CLI `pareto` subcommand (tables + JSON artifact +
//! optional simulation), `coordinator::policy` (the knee/budget period
//! policies), and `examples/exascale_study`.

pub mod epsilon;
pub mod family;
pub mod frontier;
pub mod knee;
pub mod online;
pub mod validate;

pub use epsilon::{min_energy_with_time_overhead, min_time_with_energy_overhead, EpsSolution};
pub use family::{family_frontiers, FamilyFrontier};
pub use frontier::{Frontier, FrontierPoint, FrontierSummary};
pub use knee::{Knee, KneeMethod};
pub use validate::{validate, FrontierValidation, ValidatedPoint};
