//! Frontiers over whole scenario families, as one grid-engine batch.
//!
//! A frontier is itself a cacheable cell
//! ([`CellJob::Frontier`](crate::sweep::CellJob)): evaluating a family
//! (a power-ratio sweep, the trade-off presets, a μ scan) fans the
//! per-scenario frontier computations out on the persistent pool and
//! memoises each one process-wide — re-rendering the frontier figure or
//! re-running the CLI recomputes nothing.

use crate::model::params::Scenario;
use crate::sweep::{CellOutput, GridSpec};

use super::frontier::FrontierSummary;

/// One scenario of a family with its frontier (or `None` when the
/// scenario left the model's domain — the same clamp regime `Compare`
/// cells report).
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyFrontier {
    pub label: String,
    pub scenario: Scenario,
    pub summary: Option<FrontierSummary>,
}

/// Compute the frontier of every labelled scenario, `points` samples
/// each, as one parallel, memoised grid batch. Results are in input
/// order and independent of the thread count.
pub fn family_frontiers(
    scenarios: impl IntoIterator<Item = (String, Scenario)>,
    points: usize,
    base_seed: u64,
) -> Vec<FamilyFrontier> {
    let labelled: Vec<(String, Scenario)> = scenarios.into_iter().collect();
    let mut spec = GridSpec::new(base_seed);
    for (_, s) in &labelled {
        spec.push_frontier(*s, points);
    }
    labelled
        .into_iter()
        .zip(spec.evaluate())
        .map(|((label, scenario), r)| FamilyFrontier {
            label,
            scenario,
            summary: match r.output {
                CellOutput::Frontier(f) => f,
                ref other => unreachable!("frontier cell produced {other:?}"),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{fig1_scenario, tradeoff_presets};
    use crate::pareto::frontier::FrontierSummary;

    #[test]
    fn family_matches_direct_computation() {
        let family: Vec<(String, Scenario)> = [2.0, 5.5, 7.0]
            .into_iter()
            .map(|rho| (format!("rho{rho}"), fig1_scenario(300.0, rho)))
            .collect();
        let out = family_frontiers(family.clone(), 17, 1);
        assert_eq!(out.len(), 3);
        for (f, (label, s)) in out.iter().zip(&family) {
            assert_eq!(&f.label, label);
            let direct = FrontierSummary::compute(s, 17).unwrap();
            assert_eq!(f.summary.as_ref().unwrap(), &direct);
        }
    }

    #[test]
    fn tradeoff_presets_all_have_frontiers() {
        let family = tradeoff_presets()
            .into_iter()
            .map(|(label, s)| (label.to_string(), s));
        let out = family_frontiers(family, 9, 1);
        assert!(out.len() >= 4, "presets shrank to {}", out.len());
        for f in &out {
            let sum = f.summary.as_ref().expect("preset in domain");
            assert!(sum.points.len() >= 2, "{}: {} points", f.label, sum.points.len());
            assert!(sum.hypervolume >= 0.0 && sum.hypervolume < 1.0, "{}", f.label);
        }
    }

    #[test]
    fn family_evaluation_is_bit_stable() {
        let family: Vec<(String, Scenario)> =
            vec![("a".into(), fig1_scenario(120.0, 5.5)), ("b".into(), fig1_scenario(300.0, 7.0))];
        let x = family_frontiers(family.clone(), 33, 9);
        let y = family_frontiers(family, 33, 9);
        assert_eq!(x, y);
    }
}
