//! Frontiers over whole scenario families, as one grid-engine batch.
//!
//! A frontier is itself a cacheable cell
//! ([`CellJob::Frontier`](crate::sweep::CellJob)): evaluating a family
//! (a power-ratio sweep, the trade-off presets, a μ scan) fans the
//! per-scenario frontier computations out on the persistent pool and
//! memoises each one process-wide — re-rendering the frontier figure or
//! re-running the CLI recomputes nothing. The objective [`Backend`] is
//! part of the cell (and so of the memo key), so first-order and exact
//! families coexist in the cache without aliasing.

use crate::model::backend::Backend;
use crate::model::params::{ModelError, Scenario};
use crate::sweep::{CellOutput, GridSpec};

use super::frontier::FrontierSummary;

/// One scenario of a family with its frontier, or the [`ModelError`]
/// explaining why the scenario has none (the same clamp regime
/// `Compare` cells report as `None` — surfaced instead of dropped so
/// figure/CLI callers can print the reason).
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyFrontier {
    pub label: String,
    pub scenario: Scenario,
    pub summary: Result<FrontierSummary, ModelError>,
}

/// Compute the frontier of every labelled scenario under `backend`,
/// `points` samples each, as one parallel, memoised grid batch. Results
/// are in input order and independent of the thread count.
pub fn family_frontiers(
    scenarios: impl IntoIterator<Item = (String, Scenario)>,
    points: usize,
    base_seed: u64,
    backend: Backend,
) -> Vec<FamilyFrontier> {
    let labelled: Vec<(String, Scenario)> = scenarios.into_iter().collect();
    let mut spec = GridSpec::new(base_seed);
    for (_, s) in &labelled {
        spec.push_frontier_with(*s, points, backend);
    }
    labelled
        .into_iter()
        .zip(spec.evaluate())
        .map(|((label, scenario), r)| FamilyFrontier {
            label,
            scenario,
            summary: match r.output {
                // The cell stores the full Result, error and all.
                CellOutput::Frontier(res) => res,
                ref other => unreachable!("frontier cell produced {other:?}"),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{fig1_scenario, tradeoff_presets};
    use crate::model::exact::RecoveryModel;
    use crate::model::params::{CheckpointParams, PowerParams};
    use crate::pareto::frontier::FrontierSummary;

    #[test]
    fn family_matches_direct_computation() {
        let family: Vec<(String, Scenario)> = [2.0, 5.5, 7.0]
            .into_iter()
            .map(|rho| (format!("rho{rho}"), fig1_scenario(300.0, rho)))
            .collect();
        for backend in [Backend::FirstOrder, Backend::Exact(RecoveryModel::Ideal)] {
            let out = family_frontiers(family.clone(), 17, 1, backend);
            assert_eq!(out.len(), 3);
            for (f, (label, s)) in out.iter().zip(&family) {
                assert_eq!(&f.label, label);
                let direct = FrontierSummary::compute(s, 17, backend).unwrap();
                assert_eq!(f.summary.as_ref().unwrap(), &direct, "{}", backend.name());
            }
        }
    }

    #[test]
    fn tradeoff_presets_all_have_frontiers() {
        let family = tradeoff_presets()
            .into_iter()
            .map(|(label, s)| (label.to_string(), s));
        let out = family_frontiers(family, 9, 1, Backend::FirstOrder);
        assert!(out.len() >= 4, "presets shrank to {}", out.len());
        for f in &out {
            let sum = f.summary.as_ref().expect("preset in domain");
            assert!(sum.points.len() >= 2, "{}: {} points", f.label, sum.points.len());
            assert!(sum.hypervolume >= 0.0 && sum.hypervolume < 1.0, "{}", f.label);
        }
    }

    #[test]
    fn family_evaluation_is_bit_stable() {
        let family: Vec<(String, Scenario)> =
            vec![("a".into(), fig1_scenario(120.0, 5.5)), ("b".into(), fig1_scenario(300.0, 7.0))];
        let x = family_frontiers(family.clone(), 33, 9, Backend::FirstOrder);
        let y = family_frontiers(family, 33, 9, Backend::FirstOrder);
        assert_eq!(x, y);
    }

    #[test]
    fn out_of_domain_scenarios_carry_their_error() {
        // C >= 2*mu*b: no feasible period under any backend.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        let out = family_frontiers(
            vec![("edge".to_string(), s)],
            9,
            1,
            Backend::Exact(RecoveryModel::Restarting),
        );
        match &out[0].summary {
            Err(ModelError::OutOfDomain(msg)) => {
                assert!(msg.contains("feasible"), "{msg}");
            }
            other => panic!("expected OutOfDomain, got {other:?}"),
        }
    }
}
