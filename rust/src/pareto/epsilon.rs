//! ε-constraint solves on the exact frontier.
//!
//! The two questions practitioners actually ask of the trade-off
//! (Aupy et al.'s energy-aware-deadline formulation, arXiv:1302.3720,
//! is precisely the first one):
//!
//! * "minimise energy subject to a time overhead of at most x%", and
//! * "minimise time subject to an energy overhead of at most x%".
//!
//! Both reduce to a one-dimensional root find on the period segment
//! between `T_Time_opt` and `T_Energy_opt`: moving from one optimum
//! toward the other, the relaxed objective improves monotonically while
//! the constrained one degrades monotonically (each objective is
//! unimodal with its argmin at its own endpoint). So the constrained
//! optimum is either the far endpoint (constraint slack) or the unique
//! period where the constraint binds — found here by bisection to
//! machine precision. Solutions therefore lie **on** the frontier by
//! construction.
//!
//! The monotonicity argument only needs unimodality, which both
//! objective backends satisfy, so the solves are generic over the
//! [`Backend`] like the rest of the frontier stack.

use crate::model::backend::Backend;
use crate::model::params::{ModelError, Scenario};

/// One ε-constraint solution (a frontier point plus constraint data).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpsSolution {
    /// The optimal period under the constraint.
    pub period: f64,
    pub time: f64,
    pub energy: f64,
    /// The absolute bound the constraint imposed (minutes or mW·min).
    pub bound: f64,
    /// Whether the constraint was binding. `false` means the
    /// unconstrained optimum of the relaxed objective already satisfied
    /// the bound.
    pub binding: bool,
}

/// Minimise `E_final` subject to
/// `T_final(T) <= (1 + eps_pct/100) · T_final(T_Time_opt)`, under
/// `backend`'s objectives.
pub fn min_energy_with_time_overhead(
    s: &Scenario,
    eps_pct: f64,
    backend: Backend,
) -> Result<EpsSolution, ModelError> {
    assert!(eps_pct >= 0.0, "overhead budget must be >= 0, got {eps_pct}%");
    let tt = backend.t_time_opt(s)?;
    let te = backend.t_energy_opt(s)?;
    let bound = backend.t_final(s, tt) * (1.0 + eps_pct / 100.0);
    let feasible = |t: f64| backend.t_final(s, t) <= bound;
    Ok(solve(s, tt, te, bound, backend, feasible))
}

/// Minimise `T_final` subject to
/// `E_final(T) <= (1 + eps_pct/100) · E_final(T_Energy_opt)`, under
/// `backend`'s objectives.
pub fn min_time_with_energy_overhead(
    s: &Scenario,
    eps_pct: f64,
    backend: Backend,
) -> Result<EpsSolution, ModelError> {
    assert!(eps_pct >= 0.0, "overhead budget must be >= 0, got {eps_pct}%");
    let tt = backend.t_time_opt(s)?;
    let te = backend.t_energy_opt(s)?;
    let bound = backend.e_final(s, te) * (1.0 + eps_pct / 100.0);
    let feasible = |t: f64| backend.e_final(s, t) <= bound;
    Ok(solve(s, te, tt, bound, backend, feasible))
}

/// Walk from `from` (where the constraint holds with slack) toward
/// `target` (the relaxed objective's own optimum); return `target` if it
/// is feasible, else bisect to the binding period.
fn solve(
    s: &Scenario,
    from: f64,
    target: f64,
    bound: f64,
    backend: Backend,
    feasible: impl Fn(f64) -> bool,
) -> EpsSolution {
    debug_assert!(feasible(from), "constraint must hold at its own optimum");
    if feasible(target) {
        return EpsSolution {
            period: target,
            time: backend.t_final(s, target),
            energy: backend.e_final(s, target),
            bound,
            binding: false,
        };
    }
    let (mut a, mut b) = (from, target);
    // ~100 halvings: the bracket shrinks below one ulp of any f64 period.
    for _ in 0..100 {
        let mid = 0.5 * (a + b);
        if feasible(mid) {
            a = mid;
        } else {
            b = mid;
        }
    }
    EpsSolution {
        period: a,
        time: backend.t_final(s, a),
        energy: backend.e_final(s, a),
        bound,
        binding: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::model::exact::RecoveryModel;
    use crate::util::stats::rel_err;

    const FO: Backend = Backend::FirstOrder;

    #[test]
    fn zero_budget_returns_the_endpoint() {
        let s = fig1_scenario(300.0, 5.5);
        let tt = FO.t_time_opt(&s).unwrap();
        // The objectives are flat (quadratically) at their own optima,
        // so the binding period is only pinned to ~sqrt(eps_machine).
        let sol = min_energy_with_time_overhead(&s, 0.0, FO).unwrap();
        assert!(rel_err(sol.period, tt) < 1e-6, "period {} vs {}", sol.period, tt);
        let te = FO.t_energy_opt(&s).unwrap();
        let sol = min_time_with_energy_overhead(&s, 0.0, FO).unwrap();
        assert!(rel_err(sol.period, te) < 1e-6, "period {} vs {}", sol.period, te);
    }

    #[test]
    fn huge_budget_is_not_binding() {
        let s = fig1_scenario(300.0, 5.5);
        let sol = min_energy_with_time_overhead(&s, 1_000.0, FO).unwrap();
        assert!(!sol.binding);
        assert!(rel_err(sol.period, FO.t_energy_opt(&s).unwrap()) < 1e-12);
        let sol = min_time_with_energy_overhead(&s, 1_000.0, FO).unwrap();
        assert!(!sol.binding);
        assert!(rel_err(sol.period, FO.t_time_opt(&s).unwrap()) < 1e-12);
    }

    #[test]
    fn binding_solution_sits_exactly_on_the_bound() {
        let s = fig1_scenario(300.0, 5.5);
        for eps in [1.0, 2.0, 5.0, 8.0] {
            let sol = min_energy_with_time_overhead(&s, eps, FO).unwrap();
            assert!(sol.binding, "eps={eps}%");
            assert!(sol.time <= sol.bound * (1.0 + 1e-12));
            assert!(rel_err(sol.time, sol.bound) < 1e-9, "eps={eps}%");
        }
    }

    #[test]
    fn binding_solution_on_the_bound_under_the_exact_backend() {
        let s = fig1_scenario(120.0, 5.5);
        let b = Backend::Exact(RecoveryModel::Ideal);
        for eps in [1.0, 3.0] {
            let sol = min_energy_with_time_overhead(&s, eps, b).unwrap();
            assert!(sol.binding, "eps={eps}%");
            assert!(rel_err(sol.time, sol.bound) < 1e-9, "eps={eps}%");
            // Solution values come from the exact objectives.
            assert!(rel_err(sol.time, b.t_final(&s, sol.period)) < 1e-12);
            assert!(rel_err(sol.energy, b.e_final(&s, sol.period)) < 1e-12);
            // And the period sits between the exact optima.
            let (lo, hi) = (b.t_time_opt(&s).unwrap(), b.t_energy_opt(&s).unwrap());
            assert!((lo - 1e-9..=hi + 1e-9).contains(&sol.period), "eps={eps}%");
        }
    }

    #[test]
    fn energy_decreases_monotonically_with_budget() {
        let s = fig1_scenario(300.0, 7.0);
        let mut last = f64::INFINITY;
        for eps in [0.0, 1.0, 2.0, 4.0, 8.0, 16.0] {
            let sol = min_energy_with_time_overhead(&s, eps, FO).unwrap();
            assert!(sol.energy <= last * (1.0 + 1e-12), "eps={eps}%");
            last = sol.energy;
        }
    }

    #[test]
    fn transposed_solve_mirrors() {
        let s = fig1_scenario(120.0, 5.5);
        let sol = min_time_with_energy_overhead(&s, 3.0, FO).unwrap();
        assert!(sol.binding);
        assert!(rel_err(sol.energy, sol.bound) < 1e-9);
        // Paying more energy budget must not slow us down.
        let loose = min_time_with_energy_overhead(&s, 10.0, FO).unwrap();
        assert!(loose.time <= sol.time * (1.0 + 1e-12));
    }

    #[test]
    fn solutions_lie_between_the_optima() {
        let s = fig1_scenario(300.0, 5.5);
        let tt = FO.t_time_opt(&s).unwrap();
        let te = FO.t_energy_opt(&s).unwrap();
        let (lo, hi) = (tt.min(te), tt.max(te));
        for eps in [0.5, 3.0, 12.0] {
            let a = min_energy_with_time_overhead(&s, eps, FO).unwrap();
            let b = min_time_with_energy_overhead(&s, eps, FO).unwrap();
            for sol in [a, b] {
                assert!(
                    (lo - 1e-9..=hi + 1e-9).contains(&sol.period),
                    "eps={eps}%: period {} outside [{lo}, {hi}]",
                    sol.period
                );
            }
        }
    }
}
