//! Frontier-derived periods for *online* policies, with a quantised
//! memo.
//!
//! The adaptive controller ([`crate::coordinator::AdaptiveController`])
//! re-reads its policy period after every checkpoint/failure event. For
//! the frontier-aware policies (knee, ε-constraint budgets) a naive
//! implementation would recompute a [`Frontier`] per event — ~10⁵ model
//! evaluations per simulated run — even though consecutive events move
//! the `(C, R, μ)` estimates by fractions of a percent. This module
//! makes those re-reads cheap and *deterministic*:
//!
//! * the drifting estimates `C`, `R`, `μ` are **quantised** to three
//!   significant decimal digits before the frontier is computed, so
//!   re-estimation noise below ~0.1% maps to the same key (the
//!   controller's period-space hysteresis absorbs what remains);
//! * the period is computed **from the quantised scenario** and memoised
//!   process-wide keyed on the quantised parameter bits **and the
//!   objective backend** ([`Backend::key_word`]). The cached value is
//!   therefore a pure function of its key — results cannot depend on
//!   which thread (or which concurrently-running grid cell) computed
//!   the entry first, which keeps adaptive grid cells byte-identical
//!   across thread counts; a first-order and an exact policy tracking
//!   the same estimates can never alias each other's entries.
//!
//! The non-estimated configuration (`D`, `ω`, the power draws, `T_base`)
//! is keyed by exact bits: it does not drift online, so quantising it
//! would only alias genuinely different scenarios. Quantising `C`, `R`
//! and `μ` also quantises the paper's headline knob `ρ`-family of
//! derived ratios as far as the frontier is concerned.
//!
//! # Warm-started re-solves under drift
//!
//! A memo *miss* under the exact backend is still a fresh numeric
//! solve per frontier endpoint. Under drift, successive quantised
//! views of one scenario differ only in the drifting estimates, and
//! their optima move smoothly — so the backend seeds each endpoint
//! scan from the last argmin solved for the same drift-invariant
//! scenario family (see [`crate::model::backend`]): a 3-probe bracket
//! validation around the previous optimum replaces the ~400-point grid
//! scan, falling back to the cold scan **bit-identically** when the
//! bracket check fails (optimum drifted past its neighbours, or moved
//! to the domain edge). Hints are advisory: entries here, and every
//! period this module returns, are unchanged by warm-starting —
//! `ckpt_opt_warm_{hits,fallbacks}_total` count how often the short
//! path engages.

use crate::model::backend::Backend;
use crate::model::params::{CheckpointParams, ModelError, Scenario};
use crate::util::memo::PureMemo;

use super::epsilon::{min_energy_with_time_overhead, min_time_with_energy_overhead};
use super::frontier::Frontier;
use super::knee::KneeMethod;

/// Frontier sampling density for the online policies. Dense enough that
/// the knee grid step is ≲1% of the trade-off span; the memo makes the
/// cost a non-issue.
pub const ONLINE_FRONTIER_POINTS: usize = 129;

/// Variable-width key: the fixed policy/backend prefix plus the
/// scenario's [`Scenario::key_words`] listing (exact bits of the scalar
/// ten-word core, extended by the tier structure when the scenario
/// carries a hierarchy — scalar keys are byte-identical to the
/// pre-tier fixed-width ones modulo the container).
type MemoKey = Vec<u64>;

/// One entry per distinct quantised `(C, R, μ)` visited by a controller
/// trajectory (plus one per preset/budget/backend); see [`PureMemo`]
/// for the clearing/concurrency contract. Sized for drift sweeps: a
/// non-stationary trajectory re-keys this once per distinct quantised
/// view (true-scenario targets × estimate paths × α grid), an order of
/// magnitude more than stationary runs — [`memo_stats`] reports the
/// churn.
static MEMO: PureMemo<MemoKey> = PureMemo::new(32_768);

/// Round a positive finite value to three significant decimal digits.
/// Non-finite and non-positive inputs pass through (scenario validation
/// rejects them downstream).
pub fn quantize(x: f64) -> f64 {
    if !x.is_finite() || x <= 0.0 {
        return x;
    }
    let mut exp = x.log10().floor() as i32;
    // Guard the edge where log10 of an exact power of ten lands one ulp
    // low: the decimal mantissa below must sit in [100, 1000).
    if pow10(exp + 1) <= x {
        exp += 1;
    }
    let scale = pow10(exp - 2);
    if !(scale.is_finite() && scale > 0.0) {
        return x;
    }
    (x / scale).round() * scale
}

/// `10^e` via exact integer powers (`powi` then one division for
/// negative exponents) — correctly rounded where `powf` need not be.
fn pow10(e: i32) -> f64 {
    if e >= 0 {
        10f64.powi(e)
    } else {
        1.0 / 10f64.powi(-e)
    }
}

/// The scenario actually evaluated: estimates quantised, configuration
/// exact. Errors when the quantised estimates leave the model's domain
/// (e.g. a collapsing μ estimate) — exactly when the exact scenario is
/// at or past the domain edge too.
///
/// The tier structure is configuration, not an estimate: it passes
/// through unquantised (the effective `C`/`R` the estimators track are
/// the hierarchy's projections, which *are* quantised above).
fn quantized_scenario(s: &Scenario) -> Result<Scenario, ModelError> {
    let ckpt =
        CheckpointParams::new(quantize(s.ckpt.c), quantize(s.ckpt.r), s.ckpt.d, s.ckpt.omega)?;
    let mut q = Scenario::new(ckpt, s.power, quantize(s.mu), s.t_base)?;
    q.tiers = s.tiers;
    Ok(q)
}

/// Exact-bits key of a (policy, backend, quantised scenario) triple.
/// `tag` distinguishes the policy kind, `param` its budget (0 for
/// knees), `backend` the objective model; the scenario enters through
/// the canonical [`Scenario::key_words`] listing (tier-aware).
fn memo_key(tag: u64, param: f64, backend: Backend, q: &Scenario) -> MemoKey {
    let mut k = Vec::with_capacity(14);
    k.push(tag);
    k.push(param.to_bits());
    k.push(backend.key_word());
    k.extend(q.key_words());
    k.push(ONLINE_FRONTIER_POINTS as u64);
    k
}

/// The knee period of the scenario's time–energy frontier under
/// `method` and `backend`. Falls back to the (clamped) time-optimal
/// endpoint when the frontier is degenerate — both optima clamp
/// together, so there is no interior knee and no trade-off to split.
pub fn knee_period(s: &Scenario, method: KneeMethod, backend: Backend) -> Result<f64, ModelError> {
    let q = quantized_scenario(s)?;
    let tag = match method {
        KneeMethod::MaxDistanceToChord => 1,
        KneeMethod::MaxCurvature => 2,
    };
    MEMO.get_or_try_compute(memo_key(tag, 0.0, backend, &q), || {
        let f = Frontier::compute(&q, ONLINE_FRONTIER_POINTS, backend)?;
        Ok(match f.knee(method) {
            Some(k) => k.point.period,
            None => f.t_time_opt,
        })
    })
}

/// The period minimising energy subject to a time overhead of at most
/// `max_time_overhead_pct` percent of the time-optimal makespan
/// ([`min_energy_with_time_overhead`], memoised).
pub fn min_energy_period(
    s: &Scenario,
    max_time_overhead_pct: f64,
    backend: Backend,
) -> Result<f64, ModelError> {
    validate_budget(max_time_overhead_pct)?;
    let q = quantized_scenario(s)?;
    MEMO.get_or_try_compute(memo_key(3, max_time_overhead_pct, backend, &q), || {
        Ok(min_energy_with_time_overhead(&q, max_time_overhead_pct, backend)?.period)
    })
}

/// The period minimising time subject to an energy overhead of at most
/// `max_energy_overhead_pct` percent of the energy-optimal consumption
/// ([`min_time_with_energy_overhead`], memoised).
pub fn min_time_period(
    s: &Scenario,
    max_energy_overhead_pct: f64,
    backend: Backend,
) -> Result<f64, ModelError> {
    validate_budget(max_energy_overhead_pct)?;
    let q = quantized_scenario(s)?;
    MEMO.get_or_try_compute(memo_key(4, max_energy_overhead_pct, backend, &q), || {
        Ok(min_time_with_energy_overhead(&q, max_energy_overhead_pct, backend)?.period)
    })
}

/// Counter snapshot of the online-policy memo (hits/misses/wholesale
/// clears since process start) plus its live entry count. Drift
/// trajectories re-key this memo far more often than stationary runs —
/// one entry per distinct quantised `(C, R, μ)` along the schedule —
/// and the `info` subcommand surfaces the churn through this.
pub fn memo_stats() -> (crate::util::memo::MemoStats, usize) {
    (MEMO.stats(), MEMO.len())
}

/// Live entries per backing shard (`ckpt_cache_shard_entries`).
pub fn memo_shard_entries() -> Vec<usize> {
    MEMO.shard_entries()
}

fn validate_budget(pct: f64) -> Result<(), ModelError> {
    if !(pct.is_finite() && pct >= 0.0) {
        return Err(ModelError::Invalid(format!(
            "overhead budget must be finite and >= 0, got {pct}%"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{fig1_scenario, tradeoff_presets};
    use crate::model::exact::RecoveryModel;
    use crate::model::PowerParams;

    const FO: Backend = Backend::FirstOrder;
    const EXACT: Backend = Backend::Exact(RecoveryModel::Ideal);

    #[test]
    fn quantize_rounds_to_three_significant_digits() {
        // Values already at three significant digits are fixed points.
        for v in [10.0, 300.0, 120.0, 2.0, 0.5, 123.0, 100.0, 1000.0] {
            assert_eq!(quantize(v), v, "{v}");
        }
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs();
        assert!(close(quantize(123.456), 123.0));
        assert!(close(quantize(0.123456), 0.123));
        assert!(close(quantize(99_990.0), 100_000.0));
        // Sub-0.1% wobble maps to the same value.
        assert_eq!(quantize(300.1), quantize(300.2));
        // Idempotent.
        let q = quantize(123.456);
        assert_eq!(quantize(q), q);
        // Pass-through for values validation rejects anyway.
        assert!(quantize(f64::NAN).is_nan());
        assert_eq!(quantize(-5.0), -5.0);
        assert_eq!(quantize(0.0), 0.0);
    }

    #[test]
    fn knee_period_matches_direct_frontier_on_quantisation_fixed_points() {
        // Every preset's (C, R, μ) is exact at three significant digits,
        // so the memoised policy must agree with the direct computation —
        // under both backends.
        for backend in [FO, EXACT] {
            for (label, s) in tradeoff_presets() {
                let f = Frontier::compute(&s, ONLINE_FRONTIER_POINTS, backend).expect(label);
                for method in [KneeMethod::MaxDistanceToChord, KneeMethod::MaxCurvature] {
                    let direct = f.knee(method).expect(label).point.period;
                    let got = knee_period(&s, method, backend).expect(label);
                    assert_eq!(
                        got.to_bits(),
                        direct.to_bits(),
                        "{label} {method:?} {}",
                        backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn knee_period_lies_strictly_between_the_optima() {
        for backend in [FO, EXACT] {
            for (label, s) in tradeoff_presets() {
                let tt = backend.t_time_opt(&s).unwrap();
                let te = backend.t_energy_opt(&s).unwrap();
                let (lo, hi) = (tt.min(te), tt.max(te));
                let p = knee_period(&s, KneeMethod::MaxDistanceToChord, backend).expect(label);
                assert!(
                    p > lo && p < hi,
                    "{label} {}: knee {p} outside ({lo}, {hi})",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn backends_do_not_alias_in_the_memo() {
        let s = fig1_scenario(120.0, 5.5);
        let fo = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
        let ex = knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap();
        // At mu=120 the knee drift is >20%: if the entries aliased the
        // two reads would be equal.
        assert!((ex / fo - 1.0) > 0.05, "fo={fo} ex={ex}");
        // Re-reads stay bit-stable per backend.
        assert_eq!(
            fo.to_bits(),
            knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap().to_bits()
        );
        assert_eq!(
            ex.to_bits(),
            knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap().to_bits()
        );
    }

    #[test]
    fn drifting_resolves_match_direct_frontier_computation() {
        // A drift-style sequence of quantised views from one scenario
        // family: each exact-backend re-solve seeds the next one's
        // warm bracket (the backend hint store), and every memoised
        // period must still equal the direct frontier computation.
        for mu in [150.0, 144.0, 139.0, 133.0, 129.0] {
            let s = fig1_scenario(mu, 5.5);
            let f = Frontier::compute(&s, ONLINE_FRONTIER_POINTS, EXACT).unwrap();
            let direct = f.knee(KneeMethod::MaxDistanceToChord).unwrap().point.period;
            let got = knee_period(&s, KneeMethod::MaxDistanceToChord, EXACT).unwrap();
            assert_eq!(got.to_bits(), direct.to_bits(), "mu={mu}");
        }
    }

    #[test]
    fn eps_periods_match_the_epsilon_module() {
        let s = fig1_scenario(300.0, 5.5);
        for backend in [FO, EXACT] {
            for eps in [0.5, 2.0, 5.0] {
                let direct = min_energy_with_time_overhead(&s, eps, backend).unwrap().period;
                assert_eq!(
                    min_energy_period(&s, eps, backend).unwrap().to_bits(),
                    direct.to_bits()
                );
                let direct = min_time_with_energy_overhead(&s, eps, backend).unwrap().period;
                assert_eq!(
                    min_time_period(&s, eps, backend).unwrap().to_bits(),
                    direct.to_bits()
                );
            }
        }
    }

    #[test]
    fn memoised_reads_are_bit_stable() {
        let s = fig1_scenario(120.0, 7.0);
        let a = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
        let b = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // A sub-quantum estimate wobble hits the same memo entry.
        let mut wobble = s;
        wobble.mu = s.mu * (1.0 + 2e-4);
        let c = knee_period(&wobble, KneeMethod::MaxDistanceToChord, FO).unwrap();
        assert_eq!(a.to_bits(), c.to_bits());
    }

    #[test]
    fn degenerate_frontier_falls_back_to_the_time_endpoint() {
        // ω = 1 with β = 0: both optima clamp to T = C (see the frontier
        // degenerate-scenario test).
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 1.0).unwrap();
        let power = PowerParams::from_ratios(1.0, 0.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 300.0, 1e4).unwrap();
        let p = knee_period(&s, KneeMethod::MaxDistanceToChord, FO).unwrap();
        assert_eq!(p, s.ckpt.c);
    }

    #[test]
    fn out_of_domain_estimates_error_rather_than_panic() {
        // μ far below the overheads: quantised scenario construction
        // fails with OutOfDomain, which the controller maps to None.
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario {
            ckpt,
            power,
            mu: 10.0,
            t_base: 1000.0,
            tiers: crate::storage::TierConfig::Scalar,
        };
        for backend in [FO, EXACT] {
            assert!(knee_period(&s, KneeMethod::MaxDistanceToChord, backend).is_err());
            assert!(min_energy_period(&s, 5.0, backend).is_err());
        }
    }

    #[test]
    fn budgets_are_validated() {
        let s = fig1_scenario(300.0, 5.5);
        assert!(min_energy_period(&s, -1.0, FO).is_err());
        assert!(min_energy_period(&s, f64::NAN, FO).is_err());
        assert!(min_time_period(&s, f64::INFINITY, EXACT).is_err());
        assert!(min_energy_period(&s, 0.0, FO).is_ok());
    }
}
