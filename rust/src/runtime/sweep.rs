//! Typed facade over the `sweep_eval` artifact: evaluate the paper's
//! `(T_final, E_final)` over a period grid **through XLA**.
//!
//! This exists for the three-layer consistency check: the same formulas
//! live in three places — `model::{time,energy}` (rust), the Pallas
//! kernel (L1), and `ref.py` (oracle). `rust/tests/xla_consistency.rs`
//! asserts rust and the compiled Pallas kernel agree through PJRT.

use super::artifacts::ArtifactDir;
use super::client::{literal_f32, to_vec_f32, Executable, Runtime, RuntimeError};
use crate::model::params::Scenario;

/// Number of scenario scalars in the artifact's parameter vector — must
/// match `python/compile/kernels/sweep.py::PARAM_NAMES`.
pub const N_SWEEP_PARAMS: usize = 10;

/// Compiled `sweep_eval` ready to evaluate grids.
pub struct SweepEvaluator {
    exe: Executable,
    grid_n: usize,
}

impl SweepEvaluator {
    pub fn load(rt: &Runtime, dir: &ArtifactDir) -> Result<Self, RuntimeError> {
        let exe = rt.load_hlo_text(&dir.hlo_path("sweep_eval"))?;
        Ok(SweepEvaluator { exe, grid_n: dir.sweep_grid_n })
    }

    /// Grid size the artifact was lowered for.
    pub fn grid_n(&self) -> usize {
        self.grid_n
    }

    /// Pack a [`Scenario`] into the artifact's parameter vector.
    pub fn pack_params(s: &Scenario) -> [f32; N_SWEEP_PARAMS] {
        [
            s.ckpt.c as f32,
            s.ckpt.r as f32,
            s.ckpt.d as f32,
            s.ckpt.omega as f32,
            s.mu as f32,
            s.t_base as f32,
            s.power.p_static as f32,
            s.power.p_cal as f32,
            s.power.p_io as f32,
            s.power.p_down as f32,
        ]
    }

    /// Evaluate `(T_final, E_final)` for each period in `t_grid`
    /// (`t_grid.len()` must equal [`SweepEvaluator::grid_n`]).
    pub fn eval(
        &self,
        s: &Scenario,
        t_grid: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>), RuntimeError> {
        if t_grid.len() != self.grid_n {
            return Err(RuntimeError::Artifact(format!(
                "sweep artifact lowered for {} periods, got {}",
                self.grid_n,
                t_grid.len()
            )));
        }
        let params = Self::pack_params(s);
        let out = self.exe.call(&[literal_f32(t_grid), literal_f32(&params)])?;
        if out.len() != 2 {
            return Err(RuntimeError::Artifact(format!(
                "sweep artifact returned {}-tuple, expected 2",
                out.len()
            )));
        }
        Ok((to_vec_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// Build a uniform grid spanning the scenario's feasible periods.
    pub fn uniform_grid(&self, s: &Scenario) -> Vec<f32> {
        let (_, hi) = s.domain();
        let lo = s.min_period() * 1.01;
        let hi = (hi * 0.99).max(lo * 2.0);
        (0..self.grid_n)
            .map(|i| (lo + (hi - lo) * i as f64 / (self.grid_n - 1) as f64) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::{CheckpointParams, PowerParams};

    fn scenario() -> Scenario {
        let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = PowerParams::new(10.0, 10.0, 100.0, 0.0).unwrap();
        Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
    }

    #[test]
    fn pack_params_layout_matches_python() {
        // Order must match sweep.py PARAM_NAMES:
        // c r d omega mu t_base p_static p_cal p_io p_down.
        let p = SweepEvaluator::pack_params(&scenario());
        assert_eq!(
            p,
            [10.0, 10.0, 1.0, 0.5, 300.0, 10_000.0, 10.0, 10.0, 100.0, 0.0]
        );
    }

    // Execution tests live in rust/tests/xla_consistency.rs (they need
    // the compiled artifacts).
}
