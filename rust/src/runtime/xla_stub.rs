//! Std-only stand-in for the vendored `xla` (PJRT) crate.
//!
//! The offline build image does not ship the XLA extension, so the crate
//! compiles against this stub unless the `pjrt` feature is enabled. The
//! stub keeps the *data* half of the API fully functional — [`Literal`]
//! construction, reshaping and host readback, which the workload/
//! coordinator unit tests exercise — while the *execution* half
//! ([`HloModuleProto::from_text_file`] onwards) reports the backend as
//! unavailable with an actionable message. Code paths that never execute
//! an artifact (model, simulator, sweep engine, figures, CLI except
//! `train`) behave identically with stub and real backend.

use std::borrow::Borrow;

/// Stub error: carries the message the real `xla::Error` would.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT backend; rebuild with `--features pjrt` \
         (and the vendored `xla` crate) to execute compiled artifacts"
    ))
}

/// Typed storage for stub literals.
#[derive(Debug, Clone, PartialEq)]
#[doc(hidden)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types the stub can store (mirrors the subset of the real
/// crate's `NativeType` this repo uses).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn slice(data: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn slice(data: &Data) -> Option<&[Self]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn slice(data: &Data) -> Option<&[Self]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-resident typed array with a shape — functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal { dims: vec![xs.len() as i64], data: T::wrap(xs.to_vec()) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![x]) }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    /// Copy the elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::slice(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// First element (scalar readback).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        T::slice(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("empty or mistyped literal".into()))
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(items) => Ok(items),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module — never constructible in the stub.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("loading HLO text"))
    }
}

/// Computation wrapper (only reachable with a real proto).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub CPU client: constructible (so artifact-path validation and the
/// pure-literal helpers stay testable) but unable to compile.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (pjrt feature disabled)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compilation"))
    }
}

/// Compiled executable — never constructible in the stub.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execution"))
    }
}

/// Device buffer — never constructible in the stub.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("device readback"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(lit.element_count(), 6);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit.reshape(&[4, 4]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn scalar_first_element() {
        let lit = Literal::scalar(2.5f32);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 2.5);
        assert_eq!(lit.element_count(), 1);
    }

    #[test]
    fn execution_paths_report_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let err = HloModuleProto::from_text_file("/tmp/whatever.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
