//! CPU PJRT client + compiled-executable wrapper.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation happens once per artifact at
//! startup; the hot path is `Executable::call`.

use std::path::Path;

// Without the `pjrt` feature the vendored `xla` crate is absent; compile
// against the std-only stub, which keeps every signature intact and
// reports the backend as unavailable at artifact-load time.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// Errors from the runtime layer.
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Artifact(String),
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(m) => write!(f, "xla error: {m}"),
            RuntimeError::Artifact(m) => write!(f, "artifact error: {m}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Owns the PJRT client. Create one per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Connect to the CPU PJRT backend.
    pub fn cpu() -> Result<Self, RuntimeError> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable, RuntimeError> {
        if !path.exists() {
            return Err(RuntimeError::Artifact(format!(
                "missing artifact {} — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: path.file_name().unwrap().to_string_lossy().into_owned() })
    }
}

/// A compiled artifact. `call` executes with literal inputs and splits the
/// tuple output (all our artifacts are lowered with `return_tuple=True`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs; returns the tuple elements.
    /// Generic over `Borrow<Literal>` so the hot path can pass
    /// references to persistent literals without copying them.
    pub fn call<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.exe.execute::<L>(inputs)?;
        let literal = result[0][0].to_literal_sync()?;
        Ok(literal.to_tuple()?)
    }
}

/// Build an `f32[n]` literal from a slice.
pub fn literal_f32(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Build an `i32[rows, cols]` literal from a flat slice.
pub fn literal_i32_2d(xs: &[i32], rows: usize, cols: usize) -> Result<xla::Literal, RuntimeError> {
    assert_eq!(xs.len(), rows * cols);
    Ok(xla::Literal::vec1(xs).reshape(&[rows as i64, cols as i64])?)
}

/// Build a scalar f32 literal.
pub fn literal_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Copy a literal out into a Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>, RuntimeError> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 out of a literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32, RuntimeError> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The PJRT client tests live in rust/tests/runtime_integration.rs
    // (they need the artifacts directory); here we only test the pure
    // helpers.

    #[test]
    fn literal_roundtrip_f32() {
        let lit = literal_f32(&[1.0, 2.5, -3.0]);
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn literal_i32_2d_shape() {
        let lit = literal_i32_2d(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn literal_scalar() {
        let lit = literal_scalar_f32(7.25);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.25);
    }

    #[test]
    fn missing_artifact_is_reported() {
        // Runtime::cpu() is heavier; constructing it here is fine (CPU
        // client exists everywhere the tests run).
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")) {
            Ok(_) => panic!("load of missing artifact unexpectedly succeeded"),
            Err(e) => e,
        };
        match err {
            RuntimeError::Artifact(msg) => assert!(msg.contains("make artifacts")),
            other => panic!("unexpected error {other:?}"),
        }
    }
}
