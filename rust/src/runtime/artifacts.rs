//! `artifacts/` directory schema — the contract between `python/compile`
//! and the rust runtime — plus the writer for machine-readable
//! experiment artifacts the CLI emits (`pareto --out`, run reports).

use std::path::{Path, PathBuf};

use super::client::RuntimeError;
use crate::util::json::{parse, Json};

/// Write a machine-readable experiment artifact as pretty-printed JSON,
/// creating parent directories. Every JSON file the CLI emits goes
/// through here so artifacts share one writer (stable key order via
/// [`Json`], trailing newline, directories created on demand).
pub fn write_json_artifact(path: &Path, doc: &Json) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut text = doc.to_string_pretty();
    text.push('\n');
    std::fs::write(path, text)
}

/// Binary sibling of [`write_json_artifact`]: same parent-directory
/// behaviour, raw bytes instead of JSON (the serve layer's fixed-offset
/// answer encoding, [`crate::serve::wire`], goes through here).
pub fn write_binary_artifact(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, bytes)
}

/// Streaming sibling of the writers above: create (truncate) an
/// artifact file for incremental appends, with the same
/// parent-directory behaviour. The JSONL decision-trace sink
/// ([`crate::telemetry::trace`]) writes through this — a trace is an
/// artifact like any other, it just grows line by line.
pub fn create_artifact_file(path: &Path) -> std::io::Result<std::fs::File> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::File::create(path)
}

/// One entry of the flat-parameter manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed view of `artifacts/` (meta.json + lazily-loaded blobs).
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    root: PathBuf,
    /// Model/optimizer sizing baked at AOT time.
    pub n_params: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub lr: f64,
    /// Period-sweep grid size baked at AOT time.
    pub sweep_grid_n: usize,
    pub manifest: Vec<ParamEntry>,
}

impl ArtifactDir {
    /// Parse `<root>/meta.json` and validate internal consistency.
    pub fn open(root: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        let root = root.as_ref().to_path_buf();
        let meta_path = root.join("meta.json");
        let raw = std::fs::read_to_string(&meta_path).map_err(|e| {
            RuntimeError::Artifact(format!(
                "cannot read {} ({e}) — run `make artifacts` first",
                meta_path.display()
            ))
        })?;
        let meta =
            parse(&raw).map_err(|e| RuntimeError::Artifact(format!("meta.json: {e}")))?;

        let cfg = meta
            .get("config")
            .ok_or_else(|| RuntimeError::Artifact("meta.json missing `config`".into()))?;
        let params = meta
            .get("params")
            .ok_or_else(|| RuntimeError::Artifact("meta.json missing `params`".into()))?;
        let sweep = meta
            .get("sweep")
            .ok_or_else(|| RuntimeError::Artifact("meta.json missing `sweep`".into()))?;

        let req = |j: &Json, k: &str| -> Result<f64, RuntimeError> {
            j.req_f64(k).map_err(|e| RuntimeError::Artifact(e.to_string()))
        };

        let mut manifest = Vec::new();
        if let Some(Json::Arr(entries)) = params.get("manifest") {
            for e in entries {
                let name = e
                    .req_str("name")
                    .map_err(|e| RuntimeError::Artifact(e.to_string()))?
                    .to_string();
                let shape = e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| RuntimeError::Artifact(format!("{name}: bad shape")))?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect();
                let offset = req(e, "offset")? as usize;
                manifest.push(ParamEntry { name, shape, offset });
            }
        }

        let dir = ArtifactDir {
            root,
            n_params: req(params, "n_params")? as usize,
            batch: req(cfg, "batch")? as usize,
            seq: req(cfg, "seq")? as usize,
            vocab: req(cfg, "vocab")? as usize,
            lr: req(cfg, "lr")?,
            sweep_grid_n: req(sweep, "grid_n")? as usize,
            manifest,
        };
        dir.validate()?;
        Ok(dir)
    }

    fn validate(&self) -> Result<(), RuntimeError> {
        let mut off = 0;
        for e in &self.manifest {
            if e.offset != off {
                return Err(RuntimeError::Artifact(format!(
                    "manifest gap at `{}`: offset {} expected {off}",
                    e.name, e.offset
                )));
            }
            off += e.len();
        }
        if off != self.n_params {
            return Err(RuntimeError::Artifact(format!(
                "manifest covers {off} params, meta says {}",
                self.n_params
            )));
        }
        Ok(())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.hlo.txt"))
    }

    /// Load the initial flat parameter vector from `params.bin`.
    pub fn initial_params(&self) -> Result<Vec<f32>, RuntimeError> {
        let path = self.root.join("params.bin");
        let raw = std::fs::read(&path)?;
        if raw.len() != 4 * self.n_params {
            return Err(RuntimeError::Artifact(format!(
                "params.bin is {} bytes, expected {}",
                raw.len(),
                4 * self.n_params
            )));
        }
        let mut out = Vec::with_capacity(self.n_params);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(out)
    }

    /// Find a manifest entry by name.
    pub fn entry(&self, name: &str) -> Option<&ParamEntry> {
        self.manifest.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_artifacts(dir: &Path, n: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let meta = format!(
            r#"{{
              "config": {{"vocab": 256, "d_model": 8, "n_heads": 2,
                          "n_layers": 1, "seq": 4, "batch": 2, "d_mlp": 16,
                          "lr": 0.003}},
              "params": {{"n_params": {n}, "manifest": [
                 {{"name": "a", "shape": [2, 2], "offset": 0}},
                 {{"name": "b", "shape": [{rest}], "offset": 4}}
              ]}},
              "sweep": {{"grid_n": 256}}
            }}"#,
            n = n,
            rest = n - 4
        );
        std::fs::write(dir.join("meta.json"), meta).unwrap();
        let blob: Vec<u8> =
            (0..n).flat_map(|i| (i as f32 * 0.5).to_le_bytes()).collect();
        std::fs::write(dir.join("params.bin"), blob).unwrap();
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("ckpt_artifacts_ok");
        write_fake_artifacts(&dir, 10);
        let a = ArtifactDir::open(&dir).unwrap();
        assert_eq!(a.n_params, 10);
        assert_eq!(a.batch, 2);
        assert_eq!(a.seq, 4);
        assert_eq!(a.sweep_grid_n, 256);
        assert_eq!(a.entry("a").unwrap().len(), 4);
        assert_eq!(a.entry("b").unwrap().offset, 4);
        let p = a.initial_params().unwrap();
        assert_eq!(p.len(), 10);
        assert_eq!(p[3], 1.5);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_manifest_gap() {
        let dir = std::env::temp_dir().join("ckpt_artifacts_gap");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"config": {"vocab":1,"batch":1,"seq":1,"lr":0.1},
                "params": {"n_params": 8, "manifest": [
                  {"name": "a", "shape": [2], "offset": 0},
                  {"name": "b", "shape": [2], "offset": 4}]},
                "sweep": {"grid_n": 128}}"#,
        )
        .unwrap();
        let err = ArtifactDir::open(&dir).unwrap_err();
        assert!(err.to_string().contains("manifest gap"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_bad_params_bin_size() {
        let dir = std::env::temp_dir().join("ckpt_artifacts_size");
        write_fake_artifacts(&dir, 10);
        std::fs::write(dir.join("params.bin"), [0u8; 12]).unwrap();
        let a = ArtifactDir::open(&dir).unwrap();
        assert!(a.initial_params().is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_meta_mentions_make_artifacts() {
        let err = ArtifactDir::open("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn json_artifact_roundtrips_and_creates_dirs() {
        let dir = std::env::temp_dir().join("ckpt_json_artifact").join("nested");
        let path = dir.join("pareto.json");
        let doc = Json::obj(vec![
            ("schema", Json::Str("test/v1".into())),
            ("values", Json::arr_f64(&[1.0, 2.5])),
        ]);
        write_json_artifact(&path, &doc).unwrap();
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(raw.ends_with('\n'));
        assert_eq!(parse(raw.trim()).unwrap(), doc);
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("ckpt_json_artifact"));
    }
}
