//! PJRT runtime: loads the AOT artifacts produced by `python/compile` and
//! executes them on the request path. Python is never involved here.
//!
//! * [`client`] — thin wrapper over the `xla` crate: CPU PJRT client,
//!   HLO-text loading (`HloModuleProto::from_text_file`), compilation,
//!   tuple-returning execution.
//! * [`artifacts`] — `artifacts/` directory schema: `meta.json` parsing,
//!   parameter manifest, initial `params.bin` loading, integrity checks.
//! * [`sweep`] — typed facade over the `sweep_eval` artifact: evaluate
//!   `(T_final, E_final)` grids through XLA (used by the three-layer
//!   consistency test and the figure harness's `--via-xla` mode).
//! * [`xla_stub`] (no `pjrt` feature) — std-only stand-in for the
//!   vendored `xla` crate: literals work, execution reports the backend
//!   as unavailable. Enable `pjrt` to link the real PJRT client.

pub mod artifacts;
pub mod client;
pub mod sweep;
#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

pub use artifacts::{write_binary_artifact, write_json_artifact, ArtifactDir, ParamEntry};
pub use client::{Executable, Runtime, RuntimeError};
pub use sweep::SweepEvaluator;
