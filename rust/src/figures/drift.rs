//! Drift tracking: how well the online knee controller follows a
//! non-stationary environment (beyond the paper).
//!
//! For every [`drift_presets`] family the figure sweeps the
//! controller's two knobs — the C/R EWMA smoothing α and the
//! period-space hysteresis band — crossed with the drift speed
//! ([`DriftProcess::time_scaled`]), on the Fig. 1 reference scenario
//! under the first-order knee policy, plus one exact-backend reference
//! row per family at the default knobs. Each cell is a
//! [`CellJob::DriftRun`](crate::sweep::CellJob::DriftRun): the
//! estimating controller and its clairvoyant oracle twin run on the
//! same seeds, and the cell reports
//!
//! * **tracking lag** — mean relative distance between the period in
//!   force and the instantaneous knee of the *true* drifting scenario,
//!   split into the raw gap (`tracking_lag_pct`, which folds in the μ
//!   exposure-estimator's sampling noise — α-independent by
//!   construction) and the noise-cancelled component the EWMA α
//!   actually controls (`drift_lag_pct`: both periods evaluated at the
//!   controller's own μ estimate, so only the C/R tracking error
//!   remains);
//! * **%-waste regret** — the waste gap to the oracle (and its energy
//!   twin), i.e. what estimation lag actually costs. Near the knee the
//!   frontier is flat to first order, so regret is small even where
//!   lag is large — the knee is a forgiving operating point, which is
//!   itself a finding.
//!
//! The α × band sweep shares seeds per schedule (the grid engine
//! derives `DriftRun` seeds without the controller knobs), so "lag
//! decreases monotonically in α at a fixed band" is a paired
//! comparison, gated in `tests/figure_golden.rs`. For the `mu-decay`
//! family α is expected to be flat: μ is tracked by the exposure
//! estimator, which the EWMA knob does not touch.

use crate::config::presets::{drift_presets, fig1_scenario};
use crate::coordinator::policy::PeriodPolicy;
use crate::drift::DriftProcess;
use crate::model::{Backend, RecoveryModel};
use crate::pareto::KneeMethod;
use crate::sweep::{CellOutput, DriftSummary, GridSpec};
use crate::util::table::{fnum, Table};

/// EWMA smoothing grid. Spread toward the low end where the tracking
/// lag of a ramp (`Δ·(1−α)/α` per observation) changes fastest.
pub const ALPHAS: [f64; 4] = [0.05, 0.2, 0.5, 0.9];

/// Hysteresis-band grid (`0.05` is the controller default).
pub const BANDS: [f64; 3] = [0.0, 0.05, 0.1];

/// Drift-speed grid: the preset schedules as-is and compressed 4×.
pub const SPEEDS: [f64; 2] = [1.0, 4.0];

/// The reference knobs the per-family headline and the exact-backend
/// rows use: `(alpha, hysteresis)`.
pub const REFERENCE_KNOBS: (f64, f64) = (0.2, 0.05);

fn knee(backend: Backend) -> PeriodPolicy {
    PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, backend }
}

/// One (family, model, speed, α, band) row of `drift.csv`.
#[derive(Debug, Clone)]
pub struct DriftRow {
    pub family: &'static str,
    /// Objective backend of the knee policy (`first-order` for the
    /// main grid, `exact:ideal` for the reference rows).
    pub model: &'static str,
    pub speed: f64,
    pub alpha: f64,
    pub hysteresis: f64,
    /// Raw gap to the true instantaneous knee (folds in the
    /// α-independent μ-estimator sampling noise).
    pub tracking_lag_pct: f64,
    /// μ-noise-cancelled drift-tracking lag — the component α controls
    /// (the monotonicity gate reads this column).
    pub drift_lag_pct: f64,
    /// `(makespan/T_base − 1)·100` of the estimating controller.
    pub waste_pct: f64,
    /// The oracle twin's waste.
    pub oracle_waste_pct: f64,
    pub waste_regret_pct: f64,
    pub energy_regret_pct: f64,
    pub final_period_mean: f64,
    pub period_updates_mean: f64,
    pub failures_mean: f64,
}

impl DriftRow {
    fn from_summary(
        family: &'static str,
        model: &'static str,
        speed: f64,
        alpha: f64,
        hysteresis: f64,
        t_base: f64,
        sum: &DriftSummary,
    ) -> Self {
        DriftRow {
            family,
            model,
            speed,
            alpha,
            hysteresis,
            tracking_lag_pct: sum.adaptive.tracking_lag_pct_mean,
            drift_lag_pct: sum.adaptive.drift_lag_pct_mean,
            waste_pct: (sum.adaptive.makespan_mean / t_base - 1.0) * 100.0,
            oracle_waste_pct: (sum.oracle_makespan_mean / t_base - 1.0) * 100.0,
            waste_regret_pct: sum.waste_regret_pct,
            energy_regret_pct: sum.energy_regret_pct,
            final_period_mean: sum.adaptive.final_period_mean,
            period_updates_mean: sum.adaptive.period_updates_mean,
            failures_mean: sum.adaptive.failures_mean,
        }
    }
}

/// Run the full drift grid, `replicates` sample paths per cell (each
/// cell also runs its oracle twin), as one batch seeded from
/// [`super::FIGURE_SEED`]: every family × speed × α × band under the
/// first-order knee, plus one exact-backend row per family at
/// [`REFERENCE_KNOBS`] and unit speed.
pub fn series(replicates: usize) -> Vec<DriftRow> {
    let s = fig1_scenario(300.0, 5.5);
    let families = drift_presets();
    let (ref_alpha, ref_band) = REFERENCE_KNOBS;
    let exact = Backend::Exact(RecoveryModel::Ideal);

    let mut spec = GridSpec::new(super::FIGURE_SEED);
    // (family, model, speed, alpha, band) in push order.
    let mut plan: Vec<(&'static str, &'static str, f64, f64, f64)> = Vec::new();
    for &(family, drift) in &families {
        for speed in SPEEDS {
            let schedule = drift.time_scaled(speed);
            for alpha in ALPHAS {
                for band in BANDS {
                    spec.push_drift(
                        s,
                        knee(Backend::FirstOrder),
                        replicates,
                        schedule,
                        alpha,
                        band,
                    );
                    plan.push((family, Backend::FirstOrder.name(), speed, alpha, band));
                }
            }
        }
        spec.push_drift(s, knee(exact), replicates, drift, ref_alpha, ref_band);
        plan.push((family, exact.name(), 1.0, ref_alpha, ref_band));
    }

    let results = spec.evaluate();
    plan.into_iter()
        .zip(results)
        .filter_map(|((family, model, speed, alpha, band), r)| match r.output {
            CellOutput::Drift(Some(sum)) => Some(DriftRow::from_summary(
                family, model, speed, alpha, band, s.t_base, &sum,
            )),
            // A schedule at the domain edge is skipped, like the other
            // figures' clamped cells, not a crash.
            CellOutput::Drift(None) => None,
            ref other => unreachable!("drift cell produced {other:?}"),
        })
        .collect()
}

/// `drift.csv`: one row per (family, model, speed, α, band).
pub fn table(rows: &[DriftRow]) -> Table {
    let mut t = Table::new(&[
        "family",
        "model",
        "speed",
        "alpha",
        "hysteresis",
        "tracking_lag_pct",
        "drift_lag_pct",
        "waste_pct",
        "oracle_waste_pct",
        "waste_regret_pct",
        "energy_regret_pct",
        "final_period_min",
        "period_updates",
        "failures",
    ]);
    for r in rows {
        t.row(&[
            r.family.to_string(),
            r.model.to_string(),
            fnum(r.speed, 2),
            fnum(r.alpha, 2),
            fnum(r.hysteresis, 2),
            fnum(r.tracking_lag_pct, 3),
            fnum(r.drift_lag_pct, 3),
            fnum(r.waste_pct, 3),
            fnum(r.oracle_waste_pct, 3),
            fnum(r.waste_regret_pct, 3),
            fnum(r.energy_regret_pct, 3),
            fnum(r.final_period_mean, 2),
            fnum(r.period_updates_mean, 1),
            fnum(r.failures_mean, 1),
        ]);
    }
    t
}

/// The first-order `(α, lag)` profile of one family at a fixed band
/// and speed, sorted by α ascending. `raw = false` reads the
/// μ-noise-cancelled [`DriftRow::drift_lag_pct`] (the monotonicity
/// acceptance); `raw = true` the headline [`DriftRow::tracking_lag_pct`].
pub fn lag_by_alpha(
    rows: &[DriftRow],
    family: &str,
    speed: f64,
    band: f64,
    raw: bool,
) -> Vec<(f64, f64)> {
    let mut out: Vec<(f64, f64)> = rows
        .iter()
        .filter(|r| {
            r.family == family
                && r.model == Backend::FirstOrder.name()
                && r.speed == speed
                && r.hysteresis == band
        })
        .map(|r| (r.alpha, if raw { r.tracking_lag_pct } else { r.drift_lag_pct }))
        .collect();
    out.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite alphas"));
    out
}

/// Per-family headline at [`REFERENCE_KNOBS`], unit speed,
/// first-order: `(family, tracking_lag_pct, waste_regret_pct)`.
pub fn headlines(rows: &[DriftRow]) -> Vec<(&'static str, f64, f64)> {
    let (ref_alpha, ref_band) = REFERENCE_KNOBS;
    rows.iter()
        .filter(|r| {
            r.model == Backend::FirstOrder.name()
                && r.speed == 1.0
                && r.alpha == ref_alpha
                && r.hysteresis == ref_band
        })
        .map(|r| (r.family, r.tracking_lag_pct, r.waste_regret_pct))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_covers_the_grid_and_the_exact_reference_rows() {
        let rows = series(6);
        let families = drift_presets();
        let per_family = SPEEDS.len() * ALPHAS.len() * BANDS.len() + 1;
        assert_eq!(rows.len(), families.len() * per_family);
        for (family, _) in &families {
            let fo = rows
                .iter()
                .filter(|r| r.family == *family && r.model == "first-order")
                .count();
            assert_eq!(fo, per_family - 1, "{family}");
            let exact =
                rows.iter().filter(|r| r.family == *family && r.model == "exact:ideal").count();
            assert_eq!(exact, 1, "{family}");
        }
        assert_eq!(table(&rows).n_rows(), rows.len());
        // Headlines: one per family.
        assert_eq!(headlines(&rows).len(), families.len());
        // The α profile is complete at every (speed, band).
        for speed in SPEEDS {
            for band in BANDS {
                let prof = lag_by_alpha(&rows, "io-ramp", speed, band, false);
                assert_eq!(prof.len(), ALPHAS.len(), "speed={speed} band={band}");
            }
        }
    }

    #[test]
    fn series_is_deterministic() {
        let a = series(4);
        let b = series(4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tracking_lag_pct.to_bits(), y.tracking_lag_pct.to_bits());
            assert_eq!(x.waste_regret_pct.to_bits(), y.waste_regret_pct.to_bits());
        }
    }

    #[test]
    fn oracle_waste_is_positive_and_lag_is_real() {
        let rows = series(6);
        for r in &rows {
            assert!(r.oracle_waste_pct > 0.0, "{}: oracle waste {}", r.family, r.oracle_waste_pct);
            assert!(r.failures_mean > 0.0, "{}: no failures", r.family);
            assert!(
                r.tracking_lag_pct >= 0.0 && r.tracking_lag_pct < 100.0,
                "{}: lag {} out of range",
                r.family,
                r.tracking_lag_pct
            );
        }
    }
}
