//! Frontier figure (beyond the paper): per-scenario time–energy Pareto
//! frontiers and their knees over the trade-off presets.
//!
//! The paper reports only the two endpoints of each trade-off (AlgoT
//! and AlgoE) and their ratios; this figure renders the whole curve the
//! §5 discussion walks along — measured trade-off curves being the
//! artifact practitioners actually consume (cf. the cluster energy
//! characterisation literature). Frontiers are evaluated as
//! [`CellJob::Frontier`](crate::sweep::CellJob) cells on the persistent
//! pool, memoised like every other grid.

use crate::config::presets::tradeoff_presets;
use crate::model::Backend;
use crate::pareto::{family_frontiers, FamilyFrontier};
use crate::util::table::{fnum, Table};

/// The labelled trade-off presets this figure plots.
pub fn presets() -> Vec<(String, crate::model::Scenario)> {
    tradeoff_presets().into_iter().map(|(label, s)| (label.to_string(), s)).collect()
}

/// Compute every preset's first-order frontier at `points` samples, as
/// one grid batch seeded from [`super::FIGURE_SEED`]. (The first-order
/// vs exact comparison lives in [`super::knee_drift`].)
pub fn series(points: usize) -> Vec<FamilyFrontier> {
    family_frontiers(presets(), points, super::FIGURE_SEED, Backend::FirstOrder)
}

/// One row per frontier point: the full curves, CSV-ready.
pub fn table(frontiers: &[FamilyFrontier]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "period_min",
        "makespan_min",
        "energy_mW_min",
        "time_overhead_pct",
        "energy_gain_pct",
    ]);
    for f in frontiers {
        let Ok(sum) = &f.summary else { continue };
        for p in &sum.points {
            t.row(&[
                f.label.clone(),
                fnum(p.period, 3),
                fnum(p.time, 2),
                fnum(p.energy, 2),
                fnum(sum.time_overhead_pct(p), 3),
                fnum(sum.energy_gain_pct(p), 3),
            ]);
        }
    }
    t
}

/// One row per scenario: endpoints, hypervolume, and both knees.
pub fn knee_table(frontiers: &[FamilyFrontier]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "T_time_min",
        "T_energy_min",
        "hypervolume",
        "knee_chord_period",
        "knee_chord_time_overhead_pct",
        "knee_chord_energy_gain_pct",
        "knee_curv_period",
    ]);
    for f in frontiers {
        let Ok(sum) = &f.summary else { continue };
        let chord = sum.knee_chord.as_ref();
        let curv = sum.knee_curvature.as_ref();
        t.row(&[
            f.label.clone(),
            fnum(sum.t_time_opt, 2),
            fnum(sum.t_energy_opt, 2),
            fnum(sum.hypervolume, 4),
            chord.map(|k| fnum(k.point.period, 2)).unwrap_or_default(),
            chord.map(|k| fnum(sum.time_overhead_pct(&k.point), 2)).unwrap_or_default(),
            chord.map(|k| fnum(sum.energy_gain_pct(&k.point), 2)).unwrap_or_default(),
            curv.map(|k| fnum(k.point.period, 2)).unwrap_or_default(),
        ]);
    }
    t
}

/// The chord-knee headline across presets: `(label, energy_gain_pct,
/// time_overhead_pct)` at each knee — the "most of the gain for part of
/// the price" numbers.
pub fn knee_headlines(frontiers: &[FamilyFrontier]) -> Vec<(String, f64, f64)> {
    frontiers
        .iter()
        .filter_map(|f| {
            let sum = f.summary.as_ref().ok()?;
            let k = sum.knee_chord.as_ref()?;
            Some((
                f.label.clone(),
                sum.energy_gain_pct(&k.point),
                sum.time_overhead_pct(&k.point),
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_covers_every_preset() {
        let fr = series(17);
        assert_eq!(fr.len(), presets().len());
        for f in &fr {
            assert!(f.summary.is_ok(), "{} left the domain", f.label);
        }
    }

    #[test]
    fn tables_have_expected_shapes() {
        let fr = series(9);
        let pts: usize = fr
            .iter()
            .filter_map(|f| f.summary.as_ref().ok().map(|s| s.points.len()))
            .sum();
        assert_eq!(table(&fr).n_rows(), pts);
        assert_eq!(knee_table(&fr).n_rows(), fr.len());
    }

    #[test]
    fn knee_headlines_beat_the_diagonal() {
        // At every chord knee the energy-gain share exceeds the
        // time-cost share of the full trade-off — the knee's definition,
        // surfaced as the figure's headline.
        let fr = series(65);
        let heads = knee_headlines(&fr);
        assert_eq!(heads.len(), fr.len());
        for (label, gain, overhead) in &heads {
            let full = fr
                .iter()
                .find(|f| &f.label == label)
                .and_then(|f| f.summary.as_ref().ok())
                .unwrap();
            let last = full.points.last().unwrap();
            let full_gain = full.energy_gain_pct(last);
            let full_overhead = full.time_overhead_pct(last);
            assert!(
                gain / full_gain > overhead / full_overhead,
                "{label}: knee gain {gain}/{full_gain} vs overhead {overhead}/{full_overhead}"
            );
        }
    }
}
