//! The paper's in-text headline numbers (§4–§5), as checkable values.

use crate::config::presets::{fig1_scenario, fig3_scenario};
use crate::figures::fig3;
use crate::sweep::GridSpec;

/// The §5 claims, computed from the model.
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    /// "save more than 20% of energy with an MTBF of 300 min" (ρ=5.5..7).
    pub energy_gain_mu300_rho55_pct: f64,
    pub energy_gain_mu300_rho7_pct: f64,
    /// "...at the price of an increase of ~10% in execution time".
    pub time_overhead_mu300_rho55_pct: f64,
    pub time_overhead_mu300_rho7_pct: f64,
    /// "up to 30% [energy] for a time overhead of only 12%" (Fig 3).
    pub fig3_peak_energy_gain_pct: f64,
    pub fig3_peak_at_nodes: f64,
    pub fig3_time_overhead_at_peak_pct: f64,
    /// "between 10^6 and 10^7 processors" — where the peak falls.
    pub fig3_peak_in_expected_band: bool,
}

/// Compute every headline number. The two μ=300 comparisons share the
/// grid engine's memo cache with Fig. 1/Fig. 2, so a full figure suite
/// computes them once.
pub fn compute() -> Headline {
    let spec = GridSpec::compare_all(
        [fig1_scenario(300.0, 5.5), fig1_scenario(300.0, 7.0)],
        super::FIGURE_SEED,
    );
    let results = spec.evaluate();
    let h55 = *results[0].output.comparison().expect("in domain");
    let h7 = *results[1].output.comparison().expect("in domain");

    let nodes = fig3::node_grid(120);
    let pts = fig3::series(5.5, &nodes);
    let (peak_gain, peak_at) = fig3::peak_energy_gain(&pts);
    let peak_point = pts
        .iter()
        .max_by(|a, b| a.energy_ratio.partial_cmp(&b.energy_ratio).unwrap())
        .unwrap();

    Headline {
        energy_gain_mu300_rho55_pct: h55.energy_gain_pct(),
        energy_gain_mu300_rho7_pct: h7.energy_gain_pct(),
        time_overhead_mu300_rho55_pct: h55.time_overhead_pct(),
        time_overhead_mu300_rho7_pct: h7.time_overhead_pct(),
        fig3_peak_energy_gain_pct: peak_gain,
        fig3_peak_at_nodes: peak_at,
        fig3_time_overhead_at_peak_pct: (peak_point.time_ratio - 1.0) * 100.0,
        fig3_peak_in_expected_band: (1e5..1e8).contains(&peak_at),
    }
}

/// Sanity helper used by the exascale example: the largest node count for
/// which the Fig. 3 scenario is still inside the model's domain.
pub fn fig3_domain_limit(rho: f64) -> f64 {
    let mut lo = 1e5f64;
    let mut hi = 1e9f64;
    for _ in 0..60 {
        let mid = (lo * hi).sqrt();
        if fig3_scenario(mid, rho).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_matches_paper_bands() {
        let h = compute();
        // ">20% energy at mu=300": we accept 15–35% (the exact value
        // depends on the rho within the 5.5–7 band).
        assert!(
            h.energy_gain_mu300_rho7_pct > 20.0,
            "rho=7 gain {}%",
            h.energy_gain_mu300_rho7_pct
        );
        assert!(h.energy_gain_mu300_rho55_pct > 15.0);
        // "~10% time increase".
        assert!(h.time_overhead_mu300_rho55_pct < 20.0);
        // Fig 3 peak: paper says "up to 30%" gain at "only 12%" time
        // overhead; our exact argmin of the paper's E_final yields ~19%
        // at rho=5.5 (~23% at rho=7) with ~11% overhead — same shape
        // (see EXPERIMENTS.md §Fig3).
        assert!(h.fig3_peak_energy_gain_pct > 15.0 && h.fig3_peak_energy_gain_pct < 45.0);
        assert!(h.fig3_time_overhead_at_peak_pct < 25.0);
        assert!(h.fig3_peak_in_expected_band);
    }

    #[test]
    fn domain_limit_is_between_1e7_and_1e8() {
        let lim = fig3_domain_limit(5.5);
        assert!((1e7..1e8).contains(&lim), "limit={lim}");
    }
}
