//! Figure/series harness: regenerates every figure in the paper's
//! evaluation (§4) plus our ablations, as data series + CSV/JSON files.
//!
//! | function | paper artefact |
//! |----------|----------------|
//! | [`fig1::series`]    | Fig. 1 — ratios vs ρ for several μ |
//! | [`fig2::grid`]      | Fig. 2 — ratio surfaces over (μ, ρ) |
//! | [`fig3::series`]    | Fig. 3a/3b — ratios vs node count |
//! | [`headline::compute`] | §5 headline numbers |
//! | [`frontier::series`] | time–energy Pareto frontiers + knees (beyond the paper) |
//! | [`knee_drift::series`] | first-order vs exact knee drift per preset + small-μ stress rows (beyond the paper) |
//! | [`adaptive::series`] | adaptive knee policy vs AlgoT/AlgoE/Young/Daly under injected failures (beyond the paper) |
//! | [`drift::series`] | drift tracking: lag + oracle regret vs EWMA α × hysteresis band × drift speed per drift family (beyond the paper) |
//! | [`tiers::series`] | multi-level storage: 1/2/3-level hierarchy frontiers + knee shifts per preset (beyond the paper) |
//! | [`ablations`]       | ω sweep, first-order accuracy, γ sweep, MSK, Weibull robustness |
//!
//! Every series is built as a [`crate::sweep::GridSpec`] and evaluated
//! on the persistent thread pool with process-wide memoisation — a
//! figure regenerated twice (or a cell shared between two figures, e.g.
//! the Fig. 1 slice inside Fig. 2) computes once. Simulated cells
//! (the Weibull robustness ablation) derive their seeds from
//! [`FIGURE_SEED`] and the cell parameters, so figure data is
//! deterministic and thread-count-independent. The benches time the
//! same paths and the examples print/persist them.

pub mod ablations;
pub mod adaptive;
pub mod drift;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod frontier;
pub mod headline;
pub mod knee_drift;
pub mod tiers;

/// Base seed every figure/ablation grid derives its cell seeds from.
pub const FIGURE_SEED: u64 = 2013;

use std::path::Path;

use crate::config::presets::FIG3_MU_AT_1E6_MIN;
use crate::util::table::Table;

/// Write a table to `<dir>/<name>.csv`, creating the directory.
pub fn persist(table: &Table, dir: &Path, name: &str) -> std::io::Result<()> {
    table.write_csv(&dir.join(format!("{name}.csv")))
}

/// Fig. 3 MTBF law: `μ(N) = 120 min · 10⁶ / N`.
pub fn fig3_mu(n_nodes: f64) -> f64 {
    FIG3_MU_AT_1E6_MIN * 1e6 / n_nodes
}
