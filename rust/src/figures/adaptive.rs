//! Adaptive-vs-static policy comparison across the trade-off presets
//! (beyond the paper).
//!
//! For every [`tradeoff_presets`] scenario, an online
//! [`AdaptiveController`](crate::coordinator::AdaptiveController)
//! re-estimates `(C, R, μ)` along simulated sample paths and checkpoints
//! with each policy — the paper's AlgoT/AlgoE endpoints, the classical
//! Young/Daly baselines, and the frontier knee. The table reports each
//! policy's *waste* (makespan over the failure-free `T_base`) and
//! *energy overhead* (energy over the failure-free, checkpoint-free
//! floor `T_base·(P_Static + P_Cal)`), so the knee's "most of the energy
//! gain for part of the time price" claim is measured end-to-end under
//! injected failures rather than read off the closed forms. Cells run
//! as [`CellJob::AdaptiveRun`](crate::sweep::CellJob) on the persistent
//! pool, seeded from [`super::FIGURE_SEED`] and memoised like every
//! other grid.

use crate::config::presets::tradeoff_presets;
use crate::coordinator::policy::PeriodPolicy;
use crate::model::Backend;
use crate::pareto::KneeMethod;
use crate::sweep::{CellOutput, GridSpec};
use crate::util::table::{fnum, Table};

/// The policies the comparison runs, in column order.
pub fn policies() -> Vec<PeriodPolicy> {
    vec![
        PeriodPolicy::AlgoT,
        PeriodPolicy::AlgoE,
        PeriodPolicy::Young,
        PeriodPolicy::Daly,
        PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend: Backend::FirstOrder,
        },
    ]
}

/// One (preset, policy) row of the comparison.
#[derive(Debug, Clone)]
pub struct AdaptiveRow {
    pub label: String,
    pub policy: &'static str,
    /// Mean period in force at the end of a run.
    pub final_period_mean: f64,
    pub makespan_mean: f64,
    /// `(makespan / T_base − 1)·100`: time lost to checkpoints and
    /// failures.
    pub waste_pct: f64,
    pub energy_mean: f64,
    /// `(energy / (T_base·(P_Static+P_Cal)) − 1)·100`: energy above the
    /// failure-free, checkpoint-free floor.
    pub energy_overhead_pct: f64,
    pub failures_mean: f64,
}

/// Run every (preset × policy) adaptive cell, `replicates` sample paths
/// each, as one grid batch seeded from [`super::FIGURE_SEED`].
pub fn series(replicates: usize) -> Vec<AdaptiveRow> {
    let presets = tradeoff_presets();
    let pols = policies();
    let mut spec = GridSpec::new(super::FIGURE_SEED);
    for (_, s) in &presets {
        for p in &pols {
            spec.push_adaptive(*s, *p, replicates);
        }
    }
    let results = spec.evaluate();
    let mut rows = Vec::with_capacity(results.len());
    let mut it = results.into_iter();
    for (label, s) in &presets {
        for p in &pols {
            let r = it.next().expect("one result per cell");
            let sum = match r.output {
                CellOutput::Adaptive(Some(sum)) => sum,
                // A preset at the domain edge is skipped, like the
                // frontier figure does, not a crash.
                CellOutput::Adaptive(None) => continue,
                ref other => unreachable!("adaptive cell produced {other:?}"),
            };
            let e_floor = s.t_base * (s.power.p_static + s.power.p_cal);
            rows.push(AdaptiveRow {
                label: label.to_string(),
                policy: p.name(),
                final_period_mean: sum.final_period_mean,
                makespan_mean: sum.makespan_mean,
                waste_pct: (sum.makespan_mean / s.t_base - 1.0) * 100.0,
                energy_mean: sum.energy_mean,
                energy_overhead_pct: (sum.energy_mean / e_floor - 1.0) * 100.0,
                failures_mean: sum.failures_mean,
            });
        }
    }
    rows
}

/// One row per (scenario, policy): the comparison table, CSV-ready.
pub fn table(rows: &[AdaptiveRow]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "policy",
        "final_period_min",
        "makespan_min",
        "waste_pct",
        "energy_mW_min",
        "energy_overhead_pct",
        "failures",
    ]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            r.policy.to_string(),
            fnum(r.final_period_mean, 2),
            fnum(r.makespan_mean, 1),
            fnum(r.waste_pct, 2),
            fnum(r.energy_mean, 1),
            fnum(r.energy_overhead_pct, 2),
            fnum(r.failures_mean, 1),
        ]);
    }
    t
}

/// The knee-policy headline per preset:
/// `(label, knee_waste_pct, algoe_waste_pct, knee_energy_overhead_pct,
/// algot_energy_overhead_pct)` — the knee should beat AlgoE on waste and
/// AlgoT on energy.
pub fn knee_headlines(rows: &[AdaptiveRow]) -> Vec<(String, f64, f64, f64, f64)> {
    let find = |label: &str, policy: &str| {
        rows.iter().find(|r| r.label == label && r.policy == policy)
    };
    let mut labels: Vec<&str> = Vec::new();
    for r in rows {
        if !labels.contains(&r.label.as_str()) {
            labels.push(r.label.as_str());
        }
    }
    labels
        .into_iter()
        .filter_map(|label| {
            let knee = find(label, "knee")?;
            let algo_t = find(label, "algo-t")?;
            let algo_e = find(label, "algo-e")?;
            Some((
                label.to_string(),
                knee.waste_pct,
                algo_e.waste_pct,
                knee.energy_overhead_pct,
                algo_t.energy_overhead_pct,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_covers_every_preset_and_policy() {
        let rows = series(24);
        let presets = tradeoff_presets();
        assert_eq!(rows.len(), presets.len() * policies().len());
        for (label, _) in &presets {
            let n = rows.iter().filter(|r| &r.label == label).count();
            assert_eq!(n, policies().len(), "{label}");
        }
        assert_eq!(table(&rows).n_rows(), rows.len());
    }

    #[test]
    fn knee_beats_the_wrong_endpoint_on_both_axes() {
        // The acceptance shape at figure scale: on every preset the knee
        // policy's waste is below AlgoE's and its energy overhead below
        // AlgoT's. The model-level gaps are several percentage points of
        // T_base on every preset; 96 replicates put the Monte-Carlo
        // standard error far below them.
        let rows = series(96);
        let heads = knee_headlines(&rows);
        assert_eq!(heads.len(), tradeoff_presets().len());
        for (label, knee_waste, algoe_waste, knee_energy, algot_energy) in heads {
            assert!(
                knee_waste < algoe_waste,
                "{label}: knee waste {knee_waste}% !< AlgoE {algoe_waste}%"
            );
            assert!(
                knee_energy < algot_energy,
                "{label}: knee energy {knee_energy}% !< AlgoT {algot_energy}%"
            );
        }
    }

    #[test]
    fn series_is_deterministic() {
        let a = series(16);
        let b = series(16);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.makespan_mean.to_bits(), y.makespan_mean.to_bits());
            assert_eq!(x.energy_mean.to_bits(), y.energy_mean.to_bits());
        }
    }
}
