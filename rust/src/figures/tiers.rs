//! Tiers figure (beyond the paper): the time–energy trade-off of a
//! multi-level checkpoint hierarchy, compared level-by-level.
//!
//! Every trade-off preset is evaluated under each of the
//! [`tier_presets`] storage stacks — the flattened PFS baseline
//! (`tiers-1`, which canonicalises to the paper's scalar model), a
//! 2-level SSD→PFS hierarchy, and a 3-level SSD→BB→PFS hierarchy —
//! and the full frontier plus both knees is emitted per combination.
//! The headline is the knee shift: how much of the synchronous-write
//! cost a drained hierarchy converts into simultaneous time *and*
//! energy savings at the sweet spot of the curve.

use crate::config::presets::{tier_presets, tradeoff_presets};
use crate::model::{Backend, Scenario};
use crate::pareto::{family_frontiers, FamilyFrontier};
use crate::util::table::{fnum, Table};

/// Label separator between the base preset and the tier preset
/// (`fig1-rho5.5+tiers-2`). `+` keeps the label CSV- and shell-safe.
pub const LABEL_SEP: char = '+';

/// The labelled (base preset × tier preset) scenarios this figure
/// plots. Out-of-domain combinations are skipped, like every preset
/// family; the tier presets are chosen so none are today (asserted by
/// the preset tests).
pub fn presets() -> Vec<(String, Scenario)> {
    let mut out = Vec::new();
    for (base, s) in tradeoff_presets() {
        for (tname, tiers) in tier_presets() {
            if let Ok(t) = Scenario::with_tier_specs(s.ckpt, s.power, s.mu, s.t_base, &tiers) {
                out.push((format!("{base}{LABEL_SEP}{tname}"), t));
            }
        }
    }
    out
}

/// Compute every combination's first-order frontier at `points`
/// samples, as one grid batch seeded from [`super::FIGURE_SEED`].
pub fn series(points: usize) -> Vec<FamilyFrontier> {
    family_frontiers(presets(), points, super::FIGURE_SEED, Backend::FirstOrder)
}

/// One row per (scenario, tier preset): endpoints, hypervolume, and
/// the chord knee in both absolute and relative coordinates — the
/// `tiers.csv` artifact. Comparing a `tiers-2`/`tiers-3` row with the
/// `tiers-1` row of the same base preset is the level-by-level story.
pub fn table(frontiers: &[FamilyFrontier]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "tiers",
        "levels",
        "T_time_min",
        "T_energy_min",
        "time_at_T_time_min",
        "energy_at_T_energy",
        "hypervolume",
        "knee_period_min",
        "knee_time_min",
        "knee_energy",
        "knee_time_overhead_pct",
        "knee_energy_gain_pct",
    ]);
    for f in frontiers {
        let Ok(sum) = &f.summary else { continue };
        let (base, tname) = split_label(&f.label);
        let levels = f.scenario.hierarchy().map(|h| h.len()).unwrap_or(1);
        let first = sum.points.first();
        let last = sum.points.last();
        let knee = sum.knee_chord.as_ref();
        t.row(&[
            base.to_string(),
            tname.to_string(),
            format!("{levels}"),
            fnum(sum.t_time_opt, 3),
            fnum(sum.t_energy_opt, 3),
            first.map(|p| fnum(p.time, 2)).unwrap_or_default(),
            last.map(|p| fnum(p.energy, 2)).unwrap_or_default(),
            fnum(sum.hypervolume, 4),
            knee.map(|k| fnum(k.point.period, 2)).unwrap_or_default(),
            knee.map(|k| fnum(k.point.time, 2)).unwrap_or_default(),
            knee.map(|k| fnum(k.point.energy, 2)).unwrap_or_default(),
            knee.map(|k| fnum(sum.time_overhead_pct(&k.point), 2)).unwrap_or_default(),
            knee.map(|k| fnum(sum.energy_gain_pct(&k.point), 2)).unwrap_or_default(),
        ]);
    }
    t
}

/// The knee shift of every multi-level stack against the flattened
/// `tiers-1` baseline of the same base preset:
/// `(base, tiers, knee_time_delta_pct, knee_energy_delta_pct)`, both
/// deltas relative to the baseline knee (negative = the hierarchy's
/// knee is strictly better on that axis).
pub fn knee_shifts(frontiers: &[FamilyFrontier]) -> Vec<(String, String, f64, f64)> {
    let knee_of = |label: &str| {
        frontiers
            .iter()
            .find(|f| f.label == label)
            .and_then(|f| f.summary.as_ref().ok())
            .and_then(|s| s.knee_chord.as_ref())
            .map(|k| k.point)
    };
    let mut out = Vec::new();
    for f in frontiers {
        let (base, tname) = split_label(&f.label);
        if tname == "tiers-1" {
            continue;
        }
        let Some(flat) = knee_of(&format!("{base}{LABEL_SEP}tiers-1")) else { continue };
        let Some(k) = f.summary.as_ref().ok().and_then(|s| s.knee_chord.as_ref()) else {
            continue;
        };
        out.push((
            base.to_string(),
            tname.to_string(),
            (k.point.time / flat.time - 1.0) * 100.0,
            (k.point.energy / flat.energy - 1.0) * 100.0,
        ));
    }
    out
}

fn split_label(label: &str) -> (&str, &str) {
    label.split_once(LABEL_SEP).unwrap_or((label, ""))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_covers_every_combination() {
        let fr = series(17);
        assert_eq!(fr.len(), tradeoff_presets().len() * tier_presets().len());
        for f in &fr {
            assert!(f.summary.is_ok(), "{} left the domain", f.label);
        }
        assert_eq!(table(&fr).n_rows(), fr.len());
    }

    #[test]
    fn deeper_hierarchies_knee_strictly_dominates_the_flat_baseline() {
        // The acceptance headline: on every base preset the 2- and
        // 3-level stacks move the knee strictly down *and* left of the
        // flattened single-level equivalent.
        let fr = series(33);
        let shifts = knee_shifts(&fr);
        assert_eq!(shifts.len(), tradeoff_presets().len() * (tier_presets().len() - 1));
        for (base, tiers, dt, de) in &shifts {
            assert!(
                *dt < 0.0 && *de < 0.0,
                "{base}+{tiers}: knee time {dt:+.2}% / energy {de:+.2}% vs tiers-1"
            );
        }
    }
}
