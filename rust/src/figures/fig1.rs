//! Figure 1: time ratio (AlgoE/AlgoT) and energy ratio (AlgoT/AlgoE) as
//! functions of ρ, one curve per μ ∈ {30, 60, 120, 300} min.
//!
//! Parameters: C = R = 10 min, D = 1 min, γ = 0, ω = 1/2 (§4). The two
//! arrows in the paper's plot mark ρ = 5.5 and ρ = 7.

use crate::config::presets::fig1_scenario;
use crate::sweep::GridSpec;
use crate::util::table::{fnum, Table};

/// The μ values plotted in the paper (minutes).
pub const MUS: [f64; 4] = [30.0, 60.0, 120.0, 300.0];

/// The paper's two emphasised ρ values.
pub const RHO_ARROWS: [f64; 2] = [5.5, 7.0];

/// One point of the figure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub mu: f64,
    pub rho: f64,
    pub time_ratio: f64,
    pub energy_ratio: f64,
    pub t_time: f64,
    pub t_energy: f64,
}

/// Uniform ρ grid over `[1, 20]` (the plotted range).
pub fn rho_grid(n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n).map(|i| 1.0 + 19.0 * i as f64 / (n - 1) as f64).collect()
}

/// Compute the full figure: every (μ, ρ) pair, as one grid-engine batch
/// (parallel, memoised — see [`crate::sweep`]).
pub fn series(rhos: &[f64]) -> Vec<Point> {
    let axes: Vec<(f64, f64)> = MUS
        .iter()
        .flat_map(|&mu| rhos.iter().map(move |&rho| (mu, rho)))
        .collect();
    let spec = GridSpec::compare_all(
        axes.iter().map(|&(mu, rho)| fig1_scenario(mu, rho)),
        super::FIGURE_SEED,
    );
    axes.iter()
        .zip(spec.evaluate())
        .map(|(&(mu, rho), r)| {
            let cmp = r.output.comparison().expect("fig1 scenario in domain");
            Point {
                mu,
                rho,
                time_ratio: cmp.time_ratio(),
                energy_ratio: cmp.energy_ratio(),
                t_time: cmp.t_time,
                t_energy: cmp.t_energy,
            }
        })
        .collect()
}

/// Render as a table (one row per point).
pub fn table(points: &[Point]) -> Table {
    let mut t = Table::new(&[
        "mu_min",
        "rho",
        "time_ratio_E_over_T",
        "energy_ratio_T_over_E",
        "T_time_min",
        "T_energy_min",
    ]);
    for p in points {
        t.row(&[
            fnum(p.mu, 0),
            fnum(p.rho, 3),
            fnum(p.time_ratio, 5),
            fnum(p.energy_ratio, 5),
            fnum(p.t_time, 2),
            fnum(p.t_energy, 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_range() {
        let g = rho_grid(20);
        assert_eq!(g.len(), 20);
        assert_eq!(g[0], 1.0);
        assert!((g[19] - 20.0).abs() < 1e-12);
    }

    #[test]
    fn series_has_paper_shape() {
        let pts = series(&rho_grid(40));
        assert_eq!(pts.len(), 160);
        // Every ratio >= 1.
        assert!(pts.iter().all(|p| p.time_ratio >= 1.0 - 1e-12));
        assert!(pts.iter().all(|p| p.energy_ratio >= 1.0 - 1e-12));
        // Energy ratio is nondecreasing in rho at fixed mu.
        for &mu in &MUS {
            let curve: Vec<&Point> =
                pts.iter().filter(|p| p.mu == mu).collect();
            for w in curve.windows(2) {
                assert!(
                    w[1].energy_ratio >= w[0].energy_ratio - 1e-9,
                    "mu={mu} rho {} -> {}",
                    w[0].rho,
                    w[1].rho
                );
            }
        }
        // At the paper's rho=5.5, mu=300: >15% energy gain (paper: >20%
        // around here) and modest time overhead.
        let p = pts
            .iter()
            .filter(|p| p.mu == 300.0)
            .min_by(|a, b| {
                (a.rho - 5.5).abs().partial_cmp(&(b.rho - 5.5).abs()).unwrap()
            })
            .unwrap();
        assert!(p.energy_ratio > 1.18, "energy ratio {}", p.energy_ratio);
        assert!(p.time_ratio < 1.25, "time ratio {}", p.time_ratio);
    }

    #[test]
    fn larger_mu_gives_larger_gain_at_fixed_rho() {
        // The paper's Fig 1 curves are ordered by mu: bigger mu (fewer
        // failures) => AlgoE can stretch the period more => more gain.
        let pts = series(&[7.0]);
        let mut by_mu: Vec<&Point> = pts.iter().collect();
        by_mu.sort_by(|a, b| a.mu.partial_cmp(&b.mu).unwrap());
        for w in by_mu.windows(2) {
            assert!(
                w[1].energy_ratio >= w[0].energy_ratio - 1e-9,
                "mu {} -> {}",
                w[0].mu,
                w[1].mu
            );
        }
    }

    #[test]
    fn table_rows_match_points() {
        let pts = series(&rho_grid(5));
        let t = table(&pts);
        assert_eq!(t.n_rows(), pts.len());
    }
}
