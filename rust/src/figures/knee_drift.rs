//! Knee-drift table (beyond the paper): first-order vs exact knee per
//! trade-off preset, plus small-μ stress rows.
//!
//! The first-order closed forms truncate at `O(T/μ)`; the exact
//! renewal model ([`crate::model::exact`]) does not. This figure
//! quantifies what that buys *at the operating point practitioners
//! would actually pick* — the Pareto knee: for every trade-off preset
//! (and two small-μ stress rows in the VELOC-style frequent-failure
//! regime) it tabulates the chord-knee period under both backends, the
//! relative drift between them, and the knee's time-overhead /
//! energy-gain headline under its own backend's objectives. Both
//! frontiers per row are [`CellJob::Frontier`](crate::sweep::CellJob)
//! cells (the backend is part of the cache key), so the table is
//! parallel, memoised and thread-count-deterministic like every other
//! figure.
//!
//! Headline (EXPERIMENTS.md records the full numbers): the drift is
//! ~10% at the paper's μ = 300 reference point and grows to ~22% at
//! μ = 120 and ~44% at μ = 60 — checkpointing at the first-order knee
//! in that regime over-checkpoints enough to waste ~6.5% (μ = 120) to
//! ~16.7% (μ = 60) energy relative to the exact knee under the exact
//! objectives.

use crate::config::presets::{fig1_scenario, tradeoff_presets};
use crate::model::exact::RecoveryModel;
use crate::model::{Backend, Scenario};
use crate::pareto::family_frontiers;
use crate::util::table::{fnum, Table};

/// Frontier sampling density of the drift table. Fixed (rather than the
/// `figures --points` knob) so the golden rows in
/// `tests/figure_golden.rs` pin one configuration.
pub const KNEE_DRIFT_POINTS: usize = 129;

/// The exact backend the drift is measured against. `Ideal` matches the
/// first-order forms' own failure-free-recovery assumption, so the
/// drift isolates the truncation error (the `Restarting` variant moves
/// the knee by well under 1% on these rows).
pub const DRIFT_BACKEND: Backend = Backend::Exact(RecoveryModel::Ideal);

/// The scenarios the drift table covers: every trade-off preset plus
/// two small-μ stress rows (the Fig. 1 platform pushed into the
/// frequent-failure regime where the paper's approximation degrades).
pub fn drift_presets() -> Vec<(String, Scenario)> {
    let mut v: Vec<(String, Scenario)> =
        tradeoff_presets().into_iter().map(|(l, s)| (l.to_string(), s)).collect();
    for mu in [120.0, 60.0] {
        v.push((format!("fig1-rho5.5-mu{mu}"), fig1_scenario(mu, 5.5)));
    }
    v
}

/// One row of the drift table.
#[derive(Debug, Clone)]
pub struct KneeDriftRow {
    pub label: String,
    pub mu: f64,
    /// Chord-knee period under the first-order objectives.
    pub knee_first_order: f64,
    /// Chord-knee period under [`DRIFT_BACKEND`].
    pub knee_exact: f64,
    /// `(knee_exact / knee_first_order − 1)·100`.
    pub drift_pct: f64,
    /// Time overhead / energy gain at the first-order knee, measured
    /// against the first-order frontier's own AlgoT endpoint.
    pub first_order_time_overhead_pct: f64,
    pub first_order_energy_gain_pct: f64,
    /// Same headline at the exact knee under the exact objectives.
    pub exact_time_overhead_pct: f64,
    pub exact_energy_gain_pct: f64,
}

/// Compute the drift table: one first-order and one exact frontier per
/// scenario, both as memoised grid cells seeded from
/// [`super::FIGURE_SEED`]. Rows whose frontier is degenerate (no
/// interior knee) or out of domain are skipped — none of the shipped
/// presets is.
pub fn series() -> Vec<KneeDriftRow> {
    let presets = drift_presets();
    let first = family_frontiers(
        presets.clone(),
        KNEE_DRIFT_POINTS,
        super::FIGURE_SEED,
        Backend::FirstOrder,
    );
    let exact =
        family_frontiers(presets, KNEE_DRIFT_POINTS, super::FIGURE_SEED, DRIFT_BACKEND);
    first
        .into_iter()
        .zip(exact)
        .filter_map(|(fo, ex)| {
            let fo_sum = fo.summary.ok()?;
            let ex_sum = ex.summary.ok()?;
            let fo_knee = fo_sum.knee_chord.as_ref()?.point;
            let ex_knee = ex_sum.knee_chord.as_ref()?.point;
            Some(KneeDriftRow {
                label: fo.label,
                mu: fo.scenario.mu,
                knee_first_order: fo_knee.period,
                knee_exact: ex_knee.period,
                drift_pct: (ex_knee.period / fo_knee.period - 1.0) * 100.0,
                first_order_time_overhead_pct: fo_sum.time_overhead_pct(&fo_knee),
                first_order_energy_gain_pct: fo_sum.energy_gain_pct(&fo_knee),
                exact_time_overhead_pct: ex_sum.time_overhead_pct(&ex_knee),
                exact_energy_gain_pct: ex_sum.energy_gain_pct(&ex_knee),
            })
        })
        .collect()
}

/// One row per scenario: the drift table, CSV-ready (`knee_drift.csv`).
pub fn table(rows: &[KneeDriftRow]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "mu_min",
        "knee_first_order_min",
        "knee_exact_min",
        "knee_drift_pct",
        "fo_time_overhead_pct",
        "fo_energy_gain_pct",
        "exact_time_overhead_pct",
        "exact_energy_gain_pct",
    ]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            fnum(r.mu, 1),
            fnum(r.knee_first_order, 3),
            fnum(r.knee_exact, 3),
            fnum(r.drift_pct, 2),
            fnum(r.first_order_time_overhead_pct, 3),
            fnum(r.first_order_energy_gain_pct, 3),
            fnum(r.exact_time_overhead_pct, 3),
            fnum(r.exact_energy_gain_pct, 3),
        ]);
    }
    t
}

/// `(label, drift_pct)` for every row past `min_drift_pct` — the rows
/// worth calling out (with the 5% threshold: every preset, most loudly
/// the small-μ stress rows).
pub fn headlines(rows: &[KneeDriftRow], min_drift_pct: f64) -> Vec<(String, f64)> {
    rows.iter()
        .filter(|r| r.drift_pct.abs() > min_drift_pct)
        .map(|r| (r.label.clone(), r.drift_pct))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_covers_presets_and_stress_rows() {
        let rows = series();
        assert_eq!(rows.len(), drift_presets().len());
        assert!(rows.iter().any(|r| r.label == "fig1-rho5.5-mu60"));
        assert_eq!(table(&rows).n_rows(), rows.len());
    }

    #[test]
    fn exact_knee_runs_longer_everywhere_and_drifts_hardest_at_small_mu() {
        let rows = series();
        for r in &rows {
            assert!(
                r.knee_exact > r.knee_first_order,
                "{}: exact {} !> first-order {}",
                r.label,
                r.knee_exact,
                r.knee_first_order
            );
            // The acceptance threshold: >5% drift on every shipped row.
            assert!(r.drift_pct > 5.0, "{}: drift {}%", r.label, r.drift_pct);
        }
        // Drift grows as mu shrinks along the fig1 stress family.
        let d = |label: &str| rows.iter().find(|r| r.label == label).unwrap().drift_pct;
        assert!(d("fig1-rho5.5-mu60") > d("fig1-rho5.5-mu120"));
        assert!(d("fig1-rho5.5-mu120") > d("fig1-rho5.5"));
        assert!(d("fig1-rho5.5-mu60") > 40.0, "{}", d("fig1-rho5.5-mu60"));
    }

    #[test]
    fn headlines_filter_by_threshold() {
        let rows = series();
        assert_eq!(headlines(&rows, 5.0).len(), rows.len());
        let big = headlines(&rows, 20.0);
        assert!(big.iter().any(|(l, _)| l == "fig1-rho5.5-mu60"));
        assert!(big.len() < rows.len());
    }

    #[test]
    fn series_is_deterministic() {
        let a = series();
        let b = series();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.knee_first_order.to_bits(), y.knee_first_order.to_bits());
            assert_eq!(x.knee_exact.to_bits(), y.knee_exact.to_bits());
            assert_eq!(x.drift_pct.to_bits(), y.drift_pct.to_bits());
        }
    }
}
