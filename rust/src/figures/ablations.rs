//! Ablations beyond the paper (DESIGN.md §8):
//!
//! * **ω sweep** — how the optimal periods and the energy gain move from
//!   fully blocking (ω=0) to fully overlapped (ω=1) checkpoints.
//! * **first-order accuracy** — closed-form optima vs numeric argmins of
//!   the exact closed-form objectives as C/μ grows.
//! * **γ sweep** — the paper sets `P_Down = 0`; how sensitive are the
//!   ratios to that assumption?
//! * **MSK comparison** — the §3.2 side note quantified: energy penalty
//!   of checkpointing with MSK's period under the refined model.
//! * **Weibull robustness** — Monte-Carlo of AlgoT's period under
//!   per-node Weibull failures (matched platform MTBF): how far does the
//!   exponential first-order model drift when the hazard is bursty?
//!
//! The scan-shaped ablations (ω, γ, Weibull) run as
//! [`crate::sweep::GridSpec`] batches on the persistent pool.

use crate::config::presets::weibull_platform_scenario;
use crate::model::energy::{t_energy_opt_numeric, t_time_opt_numeric};
use crate::model::msk::{compare_with_msk, MskComparison};
use crate::model::params::{CheckpointParams, PowerParams, Scenario};
use crate::model::time::{t_final, t_time_opt, t_time_opt_raw};
use crate::sweep::{Cell, CellJob, GridSpec};
use crate::util::table::{fnum, Table};

/// One row of the ω sweep.
#[derive(Debug, Clone, Copy)]
pub struct OmegaRow {
    pub omega: f64,
    pub t_time: f64,
    pub t_energy: f64,
    pub energy_gain_pct: f64,
    pub time_overhead_pct: f64,
}

/// Sweep ω at the Fig. 1 reference point (μ = 300 min, ρ = 5.5), as one
/// grid-engine batch.
pub fn omega_sweep(n: usize) -> Vec<OmegaRow> {
    assert!(n >= 2);
    let omegas: Vec<f64> = (0..n).map(|i| i as f64 / (n - 1) as f64).collect();
    let spec = GridSpec::compare_all(
        omegas.iter().map(|&omega| {
            let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, omega).unwrap();
            let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
            Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
        }),
        super::FIGURE_SEED,
    );
    omegas
        .iter()
        .zip(spec.evaluate())
        .map(|(&omega, r)| {
            let cmp = r.output.comparison().expect("omega sweep in domain");
            OmegaRow {
                omega,
                t_time: cmp.t_time,
                t_energy: cmp.t_energy,
                energy_gain_pct: cmp.energy_gain_pct(),
                time_overhead_pct: cmp.time_overhead_pct(),
            }
        })
        .collect()
}

pub fn omega_table(rows: &[OmegaRow]) -> Table {
    let mut t = Table::new(&[
        "omega",
        "T_time_min",
        "T_energy_min",
        "energy_gain_pct",
        "time_overhead_pct",
    ]);
    for r in rows {
        t.row(&[
            fnum(r.omega, 3),
            fnum(r.t_time, 2),
            fnum(r.t_energy, 2),
            fnum(r.energy_gain_pct, 2),
            fnum(r.time_overhead_pct, 2),
        ]);
    }
    t
}

/// One row of the first-order accuracy study.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyRow {
    /// C/μ — the small parameter of the first-order expansion.
    pub c_over_mu: f64,
    pub t_time_formula: f64,
    pub t_time_numeric: f64,
    pub time_rel_err: f64,
    pub t_energy_quadratic: f64,
    pub t_energy_numeric: f64,
    pub energy_rel_err: f64,
}

/// Scan C/μ from 1/1000 to ~1/3 at the Fig. 1 power point.
pub fn first_order_accuracy(n: usize) -> Vec<AccuracyRow> {
    assert!(n >= 2);
    (0..n)
        .map(|i| {
            // log-spaced C/mu in [1e-3, 0.3]
            let frac = 10f64.powf(-3.0 + (2.48) * i as f64 / (n - 1) as f64);
            let mu = 300.0;
            let c = frac * mu;
            let ckpt = CheckpointParams::new(c, c, 0.1 * c, 0.5).unwrap();
            let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
            let s = Scenario::new(ckpt, power, mu, 10_000.0).unwrap();
            let tt_f = t_time_opt_raw(&s);
            let tt_n = t_time_opt_numeric(&s);
            let te_f = crate::model::energy::t_energy_opt_raw(&s);
            let te_n = t_energy_opt_numeric(&s);
            AccuracyRow {
                c_over_mu: frac,
                t_time_formula: tt_f,
                t_time_numeric: tt_n,
                time_rel_err: crate::util::stats::rel_err(tt_f, tt_n),
                t_energy_quadratic: te_f,
                t_energy_numeric: te_n,
                energy_rel_err: crate::util::stats::rel_err(te_f, te_n),
            }
        })
        .collect()
}

pub fn accuracy_table(rows: &[AccuracyRow]) -> Table {
    let mut t = Table::new(&[
        "c_over_mu",
        "T_time_eq1",
        "T_time_numeric",
        "time_rel_err",
        "T_energy_quad",
        "T_energy_numeric",
        "energy_rel_err",
    ]);
    for r in rows {
        t.row(&[
            fnum(r.c_over_mu, 5),
            fnum(r.t_time_formula, 3),
            fnum(r.t_time_numeric, 3),
            format!("{:.2e}", r.time_rel_err),
            fnum(r.t_energy_quadratic, 3),
            fnum(r.t_energy_numeric, 3),
            format!("{:.2e}", r.energy_rel_err),
        ]);
    }
    t
}

/// γ sweep at the Fig. 1 point: does `P_Down > 0` change the story?
pub fn gamma_sweep(n: usize) -> Vec<(f64, f64, f64)> {
    let gammas: Vec<f64> = (0..n).map(|i| 2.0 * i as f64 / (n - 1).max(1) as f64).collect();
    let spec = GridSpec::compare_all(
        gammas.iter().map(|&gamma| {
            let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
            let power = PowerParams::from_rho(5.5, 1.0, gamma).unwrap();
            Scenario::new(ckpt, power, 300.0, 10_000.0).unwrap()
        }),
        super::FIGURE_SEED,
    );
    gammas
        .iter()
        .zip(spec.evaluate())
        .map(|(&gamma, r)| {
            let cmp = r.output.comparison().expect("gamma sweep in domain");
            (gamma, cmp.energy_gain_pct(), cmp.time_overhead_pct())
        })
        .collect()
}

/// One row of the Weibull robustness ablation.
#[derive(Debug, Clone, Copy)]
pub struct WeibullRow {
    pub n_nodes: f64,
    pub shape: f64,
    /// AlgoT's period for the matched exponential scenario.
    pub period: f64,
    /// First-order (exponential) model prediction.
    pub model_makespan: f64,
    /// Monte-Carlo mean under per-node Weibull failures.
    pub sim_makespan: f64,
    pub sim_ci95_half: f64,
    /// |model − sim| / sim.
    pub rel_err: f64,
}

/// Simulate AlgoT's period under the bursty-hazard stress model
/// ([`weibull_platform_scenario`]: a fixed number of superposed Weibull
/// streams with the platform MTBF matched to the exponential preset),
/// across shapes and Fig. 3 node counts. `shape < 1` is the
/// infant-mortality regime real failure logs show; the row's `rel_err`
/// is how far the paper's exponential first-order model drifts when the
/// hazard is that bursty — a robustness bound, not a prediction for a
/// literal `n_nodes`-stream platform (a superposition that large tends
/// back to Poisson). Runs as one simulated grid batch (seeded,
/// parallel, memoised).
pub fn weibull_robustness(
    shapes: &[f64],
    node_counts: &[f64],
    rho: f64,
    replicates: usize,
) -> Vec<WeibullRow> {
    let mut axes = Vec::new();
    let mut spec = GridSpec::new(super::FIGURE_SEED);
    for &shape in shapes {
        for &n in node_counts {
            let Some((scenario, process)) = weibull_platform_scenario(n, rho, shape) else {
                continue;
            };
            let Ok(period) = t_time_opt(&scenario) else { continue };
            axes.push((n, shape, period, t_final(&scenario, period)));
            spec.push(Cell {
                scenario,
                failure: Some(process),
                job: CellJob::Sim { period, replicates, failures_during_recovery: true },
            });
        }
    }
    axes.iter()
        .zip(spec.evaluate())
        .map(|(&(n_nodes, shape, period, model_makespan), r)| {
            let sim = r.output.sim().expect("sim cell");
            WeibullRow {
                n_nodes,
                shape,
                period,
                model_makespan,
                sim_makespan: sim.makespan_mean,
                sim_ci95_half: sim.makespan_ci95_half,
                rel_err: (model_makespan - sim.makespan_mean).abs() / sim.makespan_mean,
            }
        })
        .collect()
}

pub fn weibull_table(rows: &[WeibullRow]) -> Table {
    let mut t = Table::new(&[
        "n_nodes",
        "shape",
        "T_algoT_min",
        "makespan_model",
        "makespan_sim",
        "ci95_half",
        "rel_err",
    ]);
    for r in rows {
        t.row(&[
            format!("{:.2e}", r.n_nodes),
            fnum(r.shape, 2),
            fnum(r.period, 2),
            fnum(r.model_makespan, 1),
            fnum(r.sim_makespan, 1),
            fnum(r.sim_ci95_half, 1),
            format!("{:.4}", r.rel_err),
        ]);
    }
    t
}

/// One row of the first-order-vs-exact (renewal) model comparison.
#[derive(Debug, Clone, Copy)]
pub struct ExactRow {
    pub mu: f64,
    /// First-order AlgoE period vs exact energy-optimal period.
    pub t_energy_first: f64,
    pub t_energy_exact: f64,
    /// Exact energy at each period: how much the first-order period
    /// actually costs (in % over the exact optimum).
    pub energy_penalty_pct: f64,
    /// Same question for AlgoT/makespan.
    pub t_time_first: f64,
    pub t_time_exact: f64,
    pub time_penalty_pct: f64,
}

/// How much the paper's first-order periods cost under the exact
/// renewal objective, across the μ range (an answer the paper could not
/// compute: the first-order model cannot price its own error).
pub fn first_order_vs_exact(mus: &[f64]) -> Vec<ExactRow> {
    use crate::model::exact::{e_final_exact, t_energy_opt_exact, t_final_exact, t_time_opt_exact, RecoveryModel};
    mus.iter()
        .map(|&mu| {
            let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
            let power = PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
            let s = Scenario::new(ckpt, power, mu, 10_000.0).unwrap();
            let m = RecoveryModel::Ideal;
            let tt_f = crate::model::t_time_opt(&s).unwrap();
            let tt_x = t_time_opt_exact(&s, m);
            let te_f = crate::model::t_energy_opt(&s).unwrap();
            let te_x = t_energy_opt_exact(&s, m);
            ExactRow {
                mu,
                t_energy_first: te_f,
                t_energy_exact: te_x,
                energy_penalty_pct: (e_final_exact(&s, te_f, m) / e_final_exact(&s, te_x, m)
                    - 1.0)
                    * 100.0,
                t_time_first: tt_f,
                t_time_exact: tt_x,
                time_penalty_pct: (t_final_exact(&s, tt_f, m) / t_final_exact(&s, tt_x, m)
                    - 1.0)
                    * 100.0,
            }
        })
        .collect()
}

pub fn exact_table(rows: &[ExactRow]) -> Table {
    let mut t = Table::new(&[
        "mu_min",
        "T_time_eq1",
        "T_time_exact",
        "time_penalty_pct",
        "T_energy_quad",
        "T_energy_exact",
        "energy_penalty_pct",
    ]);
    for r in rows {
        t.row(&[
            fnum(r.mu, 0),
            fnum(r.t_time_first, 2),
            fnum(r.t_time_exact, 2),
            fnum(r.time_penalty_pct, 3),
            fnum(r.t_energy_first, 2),
            fnum(r.t_energy_exact, 2),
            fnum(r.energy_penalty_pct, 3),
        ]);
    }
    t
}

/// MSK comparison at the paper's blocking point (ω = 0 required by MSK).
pub fn msk_comparison(mu: f64, rho: f64) -> MskComparison {
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.0).unwrap();
    let power = PowerParams::from_rho(rho, 1.0, 0.0).unwrap();
    let s = Scenario::new(ckpt, power, mu, 10_000.0).unwrap();
    compare_with_msk(&s).expect("in domain")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omega_sweep_shape() {
        let rows = omega_sweep(11);
        assert_eq!(rows.len(), 11);
        // Blocking endpoint: Eq.1 reduces to ~sqrt(2C(mu-D-R)).
        assert!((rows[0].t_time - (2.0f64 * 10.0 * (300.0 - 11.0)).sqrt()).abs() < 1.0);
        // Fully-overlapped endpoint: AlgoT clamps to C.
        assert_eq!(rows[10].t_time, 10.0);
        // Gains stay positive everywhere rho > 1.
        assert!(rows.iter().all(|r| r.energy_gain_pct >= -1e-9));
        assert_eq!(omega_table(&rows).n_rows(), 11);
    }

    #[test]
    fn first_order_err_grows_with_c_over_mu() {
        let rows = first_order_accuracy(10);
        // Small C/mu: formulas agree with numeric argmin tightly.
        assert!(rows[0].time_rel_err < 1e-5, "{:?}", rows[0]);
        assert!(rows[0].energy_rel_err < 1e-4, "{:?}", rows[0]);
        // The quadratic is the exact stationarity condition of the
        // closed-form E_final, so its error stays tiny even at large
        // C/mu; Eq. 1 likewise. What grows is the *model's* truncation
        // error (unobservable here) — we assert the optima stay finite
        // and feasible.
        for r in &rows {
            assert!(r.t_time_numeric.is_finite() && r.t_energy_numeric > 0.0);
        }
        assert_eq!(accuracy_table(&rows).n_rows(), 10);
    }

    #[test]
    fn gamma_barely_moves_the_needle() {
        // D is tiny compared to mu, so even gamma=2 shifts gains by <2pp.
        let rows = gamma_sweep(5);
        let gains: Vec<f64> = rows.iter().map(|r| r.1).collect();
        let spread = gains.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - gains.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 2.0, "spread={spread}");
    }

    #[test]
    fn first_order_penalty_small_at_large_mu_grows_at_small_mu() {
        let rows = first_order_vs_exact(&[40.0, 120.0, 300.0, 3000.0]);
        // Large mu: first-order periods are essentially free.
        let big = rows.last().unwrap();
        assert!(big.time_penalty_pct < 0.1, "{big:?}");
        assert!(big.energy_penalty_pct < 0.5, "{big:?}");
        // Small mu: the first-order period materially overpays.
        let small = &rows[0];
        assert!(
            small.time_penalty_pct > big.time_penalty_pct,
            "{small:?} vs {big:?}"
        );
        // Penalties are nonnegative by construction (exact opt is argmin).
        for r in &rows {
            assert!(r.time_penalty_pct >= -1e-9, "{r:?}");
            assert!(r.energy_penalty_pct >= -1e-9, "{r:?}");
        }
        assert_eq!(exact_table(&rows).n_rows(), 4);
    }

    #[test]
    fn msk_penalty_positive() {
        let cmp = msk_comparison(300.0, 5.5);
        assert!(cmp.penalty_pct >= 0.0);
        assert!(cmp.t_msk != cmp.t_algo_e);
    }

    #[test]
    fn weibull_robustness_rows_sane() {
        let rows = weibull_robustness(&[1.0, 0.7], &[1e6], 5.5, 80);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.sim_makespan > 0.0 && r.model_makespan > 0.0);
            assert!(r.sim_ci95_half > 0.0);
            // Matched platform MTBF: the exponential model keeps the
            // right magnitude even under bursty per-node hazards.
            assert!(r.rel_err < 0.25, "{r:?}");
        }
        // shape = 1 IS exponential in law: the model should be tight.
        let exp_row = rows.iter().find(|r| r.shape == 1.0).unwrap();
        assert!(exp_row.rel_err < 0.10, "{exp_row:?}");
        assert_eq!(weibull_table(&rows).n_rows(), 2);
        // Deterministic: same inputs, same outputs (cache or not).
        let again = weibull_robustness(&[1.0, 0.7], &[1e6], 5.5, 80);
        assert_eq!(rows[0].sim_makespan, again[0].sim_makespan);
    }
}
