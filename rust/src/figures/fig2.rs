//! Figure 2: the two ratio surfaces over the (μ, ρ) plane.
//!
//! (a) energy ratio of AlgoT over AlgoE; (b) execution-time ratio of
//! AlgoE over AlgoT. Same C/R/D/ω parameters as Fig. 1.

use crate::config::presets::fig2_scenario;
use crate::sweep::GridSpec;
use crate::util::table::{fnum, Table};

/// A grid cell of the surface.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    pub mu: f64,
    pub rho: f64,
    pub time_ratio: f64,
    pub energy_ratio: f64,
}

/// μ axis: uniform in `[30, 300]` minutes (the paper's plotted range).
pub fn mu_grid(n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n).map(|i| 30.0 + 270.0 * i as f64 / (n - 1) as f64).collect()
}

/// ρ axis: uniform in `[1, 20]`.
pub fn rho_grid(n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n).map(|i| 1.0 + 19.0 * i as f64 / (n - 1) as f64).collect()
}

/// Compute the surface row-major (μ outer, ρ inner) as one grid-engine
/// batch. A full 80×80 surface is 6 400 comparison cells — exactly the
/// shape the pool + memo cache were built for.
pub fn grid(mus: &[f64], rhos: &[f64]) -> Vec<Cell> {
    let axes: Vec<(f64, f64)> = mus
        .iter()
        .flat_map(|&mu| rhos.iter().map(move |&rho| (mu, rho)))
        .collect();
    let spec = GridSpec::compare_all(
        axes.iter().map(|&(mu, rho)| fig2_scenario(mu, rho)),
        super::FIGURE_SEED,
    );
    axes.iter()
        .zip(spec.evaluate())
        .map(|(&(mu, rho), r)| {
            let cmp = r.output.comparison().expect("fig2 scenario in domain");
            Cell { mu, rho, time_ratio: cmp.time_ratio(), energy_ratio: cmp.energy_ratio() }
        })
        .collect()
}

/// Long-format table (one row per cell) — ready for any surface plotter.
pub fn table(cells: &[Cell]) -> Table {
    let mut t = Table::new(&["mu_min", "rho", "time_ratio_E_over_T", "energy_ratio_T_over_E"]);
    for c in cells {
        t.row(&[
            fnum(c.mu, 1),
            fnum(c.rho, 3),
            fnum(c.time_ratio, 5),
            fnum(c.energy_ratio, 5),
        ]);
    }
    t
}

/// Max energy gain (%) over the surface — the number the paper's
/// conclusion quotes ("more than 20% at μ = 300").
pub fn max_energy_gain_pct(cells: &[Cell]) -> f64 {
    cells
        .iter()
        .map(|c| (1.0 - 1.0 / c.energy_ratio) * 100.0)
        .fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surface_dimensions() {
        let cells = grid(&mu_grid(5), &rho_grid(7));
        assert_eq!(cells.len(), 35);
        assert_eq!(table(&cells).n_rows(), 35);
    }

    #[test]
    fn surface_monotone_in_rho_for_energy() {
        let mus = mu_grid(4);
        let rhos = rho_grid(10);
        let cells = grid(&mus, &rhos);
        for (i, _) in mus.iter().enumerate() {
            let row = &cells[i * rhos.len()..(i + 1) * rhos.len()];
            for w in row.windows(2) {
                assert!(w[1].energy_ratio >= w[0].energy_ratio - 1e-9);
            }
        }
    }

    #[test]
    fn paper_conclusion_gain_exceeds_20pct() {
        // At mu = 300 and large rho the paper reports > 20% energy gain.
        let cells = grid(&[300.0], &rho_grid(20));
        assert!(max_energy_gain_pct(&cells) > 20.0);
    }

    #[test]
    fn unity_corner_at_rho_1() {
        // rho = 1: I/O power == CPU power, energy ~ time objective =>
        // nearly identical periods, ratios ~ 1.
        let cells = grid(&mu_grid(4), &[1.0]);
        for c in &cells {
            assert!(c.energy_ratio < 1.02, "{c:?}");
            assert!(c.time_ratio < 1.02, "{c:?}");
        }
    }
}
