//! Figure 3: time and energy ratios as functions of the node count, for
//! ρ = 5.5 (3a) and ρ = 7 (3b).
//!
//! Parameters (§4): C = R = 1 min, D = 0.1 min, γ = 0, ω = 1/2, and
//! μ = 120 min at 10⁶ nodes scaling as 1/N, N ∈ [10⁵, 10⁸].
//!
//! Beyond ~6·10⁷ nodes the first-order model leaves its domain
//! (μ approaches the checkpoint overheads); both strategies degenerate to
//! back-to-back checkpointing (`T = C`) and the ratios are exactly 1 —
//! the paper's "converge to 1" tail. [`series`] reports those points
//! with `clamped = true`.

use crate::config::presets::fig3_scenario;
use crate::sweep::GridSpec;
use crate::util::table::{fnum, Table};

/// One point of Fig. 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub n_nodes: f64,
    pub mu: f64,
    pub rho: f64,
    pub time_ratio: f64,
    pub energy_ratio: f64,
    /// True when the scenario left the model's domain and both
    /// strategies collapsed to `T = C` (ratio forced to 1).
    pub clamped: bool,
}

/// Log-uniform node-count grid over `[1e5, 1e8]`.
pub fn node_grid(n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| 10f64.powf(5.0 + 3.0 * i as f64 / (n - 1) as f64))
        .collect()
}

/// Compute one panel (fixed ρ) as a grid-engine batch. Out-of-domain
/// node counts never enter the grid; in-domain cells whose comparison
/// still fails (domain edge) come back as `Compare(None)` — both are
/// reported clamped.
pub fn series(rho: f64, nodes: &[f64]) -> Vec<Point> {
    let scenarios: Vec<_> = nodes.iter().map(|&n| (n, fig3_scenario(n, rho))).collect();
    let spec = GridSpec::compare_all(
        scenarios.iter().filter_map(|(_, s)| *s),
        super::FIGURE_SEED,
    );
    let mut results = spec.evaluate().into_iter();
    let clamped_point = |n: f64| Point {
        n_nodes: n,
        mu: super::fig3_mu(n),
        rho,
        time_ratio: 1.0,
        energy_ratio: 1.0,
        clamped: true,
    };
    scenarios
        .iter()
        .map(|&(n, s)| match s {
            Some(sc) => {
                let r = results.next().expect("one result per in-domain cell");
                match r.output.comparison() {
                    Some(cmp) => Point {
                        n_nodes: n,
                        mu: sc.mu,
                        rho,
                        time_ratio: cmp.time_ratio(),
                        energy_ratio: cmp.energy_ratio(),
                        clamped: false,
                    },
                    None => clamped_point(n),
                }
            }
            None => clamped_point(n),
        })
        .collect()
}

/// Render one panel as a table.
pub fn table(points: &[Point]) -> Table {
    let mut t = Table::new(&[
        "n_nodes",
        "mu_min",
        "rho",
        "time_ratio_E_over_T",
        "energy_ratio_T_over_E",
        "clamped",
    ]);
    for p in points {
        t.row(&[
            format!("{:.3e}", p.n_nodes),
            fnum(p.mu, 3),
            fnum(p.rho, 2),
            fnum(p.time_ratio, 5),
            fnum(p.energy_ratio, 5),
            format!("{}", p.clamped),
        ]);
    }
    t
}

/// The panel's peak energy gain (%) and where it happens.
pub fn peak_energy_gain(points: &[Point]) -> (f64, f64) {
    let best = points
        .iter()
        .max_by(|a, b| a.energy_ratio.partial_cmp(&b.energy_ratio).unwrap())
        .expect("non-empty series");
    ((1.0 - 1.0 / best.energy_ratio) * 100.0, best.n_nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_log_uniform() {
        let g = node_grid(7);
        assert!((g[0] - 1e5).abs() / 1e5 < 1e-9);
        assert!((g[6] - 1e8).abs() / 1e8 < 1e-9);
        let r1 = g[1] / g[0];
        let r2 = g[5] / g[4];
        assert!((r1 - r2).abs() < 1e-9);
    }

    #[test]
    fn paper_headline_peak_gain() {
        // §4: "up to 30% [energy gain] for a time overhead of only 12%",
        // with the maximum between 10^6 and 10^7 nodes. Our exact argmin
        // of the paper's own E_final gives 18.6% (rho=5.5) / 22.6%
        // (rho=7) at N≈5e6 with ~11-13% time overhead — same shape,
        // somewhat smaller magnitude (see EXPERIMENTS.md §Fig3 for the
        // discrepancy analysis).
        let pts = series(5.5, &node_grid(60));
        let (gain, at) = peak_energy_gain(&pts);
        assert!(gain > 15.0, "gain={gain}%");
        assert!(gain < 45.0, "gain={gain}%");
        assert!(
            (1e5..1e8).contains(&at),
            "peak at {at}"
        );
        // Time overhead at the peak point is modest.
        let peak = pts
            .iter()
            .max_by(|a, b| a.energy_ratio.partial_cmp(&b.energy_ratio).unwrap())
            .unwrap();
        assert!(peak.time_ratio < 1.30, "time ratio {}", peak.time_ratio);
    }

    #[test]
    fn rho7_gains_exceed_rho55() {
        let n = node_grid(30);
        let a = series(5.5, &n);
        let b = series(7.0, &n);
        let (gain_a, _) = peak_energy_gain(&a);
        let (gain_b, _) = peak_energy_gain(&b);
        assert!(gain_b > gain_a, "{gain_b} <= {gain_a}");
    }

    #[test]
    fn tail_converges_to_one() {
        let pts = series(5.5, &node_grid(40));
        let last = pts.last().unwrap();
        assert!(last.clamped);
        assert_eq!(last.time_ratio, 1.0);
        assert_eq!(last.energy_ratio, 1.0);
        // And the first points (small N) are finite, unclamped.
        assert!(!pts[0].clamped);
    }

    #[test]
    fn table_includes_clamp_column() {
        let pts = series(5.5, &node_grid(10));
        let t = table(&pts);
        assert_eq!(t.n_rows(), 10);
    }
}
