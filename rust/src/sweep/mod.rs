//! Batched scenario-grid engine.
//!
//! Every consumer of the model/simulator — the figure harness
//! ([`crate::figures`]), the ablations, the Pareto-frontier subsystem
//! ([`crate::pareto`]), and the CLI `sweep` / `simulate` / `figures` /
//! `pareto` subcommands — needs the same thing: "evaluate this
//! (scenario × period × failure-process) grid". This module turns that
//! into one declarative call:
//!
//! ```
//! use ckpt_period::config::presets::fig1_scenario;
//! use ckpt_period::sweep::GridSpec;
//!
//! let scenarios = [30.0, 300.0]
//!     .into_iter()
//!     .flat_map(|mu| [5.5, 7.0].into_iter().map(move |rho| fig1_scenario(mu, rho)));
//! let results = GridSpec::compare_all(scenarios, 1).evaluate();
//! assert_eq!(results.len(), 4);
//! assert!(results[0].output.comparison().unwrap().energy_ratio() >= 1.0);
//! ```
//!
//! Three properties make it the crate's single grid path:
//!
//! * **Persistent parallelism** — cells run on the process-wide
//!   work-stealing pool ([`crate::util::pool::ThreadPool`]); no thread
//!   spawn/join per call (the seed's `monte_carlo` paid ~100 µs of churn
//!   per invocation).
//! * **Deterministic seeding** — each simulated cell hashes the spec's
//!   `base_seed` with its own parameter bits ([`GridSpec::cell_seed`]),
//!   so results are byte-identical for every thread count and stable
//!   under grid re-ordering.
//! * **Memoisation** — outputs are cached process-wide keyed by exact
//!   parameter bit patterns ([`cache`]), so repeated figure/CLI/bench
//!   invocations of overlapping grids skip recomputation.
//!
//! [`grid`] holds the `GridSpec`/`Cell`/`CellResult` API; [`cache`] the
//! memo store and its counters.

pub mod cache;
pub mod grid;

pub use grid::{
    AdaptiveSummary, Cell, CellJob, CellOutput, CellResult, DriftSummary, GridSpec, SimSummary,
};
