//! In-memory memo cache for grid cells.
//!
//! Figures, ablations and the CLI repeatedly evaluate overlapping
//! (scenario × period × failure-process) cells — e.g. `headline::compute`
//! re-derives two Fig. 1 comparisons, and every bench iteration re-walks
//! the same surface. Cell evaluation is pure (seeded Monte Carlo
//! included), so results are memoised process-wide, keyed by the **exact
//! bit patterns** of every parameter that influences the output (scenario
//! floats, job kind, period, replicate count, failure process, derived
//! seed). Two cells collide only if they would compute byte-identical
//! results, so a hit is always sound.
//!
//! Storage is a [`ShardedMap`] in FIFO mode: lookups touch only the
//! key's shard (64 independent locks instead of the historical single
//! global mutex, so an 8-thread grid sweep no longer serialises on warm
//! hits), while puts keep the exact historical semantics — a global
//! insertion-order FIFO bounded by `MAX_ENTRIES`, evicting the oldest
//! quarter (one eviction event per batch) at capacity, with
//! [`set_capacity`] shrinking immediately. The cache can be bypassed
//! per-[`GridSpec`](super::GridSpec) or cleared/interrogated for tests
//! and benches.
//!
//! Hit/miss counters are per-shard, aggregated by [`stats`] into the
//! historical `(hits, misses)` shape; the unified telemetry surface
//! ([`crate::telemetry::registry::cache_rows`]) reads the same numbers,
//! and eviction events surface as the row's `clears` column.

use crate::util::shard::ShardedMap;

use super::grid::CellOutput;

/// Exact-bits cache key: every f64 is stored as `to_bits`, discrete
/// fields as tagged words (see `GridSpec::cell_key`).
pub(crate) type CellKey = Vec<u64>;

/// Default capacity bound; a full figure suite is ~10⁴ cells.
const MAX_ENTRIES: usize = 1 << 18;

static CACHE: ShardedMap<CellKey, CellOutput> = ShardedMap::fifo(MAX_ENTRIES);

pub(crate) fn get(key: &CellKey) -> Option<CellOutput> {
    // Counting lookup: every get resolves to exactly one hit or miss,
    // whether or not a `put` follows (the historical contract
    // `tests/sweep_cache.rs` pins).
    CACHE.get_counting(key)
}

pub(crate) fn put(key: CellKey, value: CellOutput) {
    CACHE.insert_if_absent(key, value);
}

/// `(hits, misses)` since process start (or the last [`reset_stats`]).
pub fn stats() -> (u64, u64) {
    CACHE.stats()
}

/// Zero the hit/miss counters (benches bracket phases with this).
pub fn reset_stats() {
    CACHE.reset_stats();
}

/// FIFO eviction events since process start (one per oldest-quarter
/// batch) — the `clears` column of the unified cache table.
pub fn evictions() -> u64 {
    CACHE.evictions()
}

/// Number of memoised cells.
pub fn len() -> usize {
    CACHE.len()
}

/// Live entries per shard (`ckpt_cache_shard_entries` exposition).
pub fn shard_entries() -> Vec<usize> {
    CACHE.shard_entries()
}

/// Drop every memoised cell (tests; cold-start benchmarking).
pub fn clear() {
    CACHE.clear();
}

/// Override the capacity bound (tests/benches exercising eviction;
/// process-global — restore [`default_capacity`] afterwards). Shrinking
/// below the current size evicts FIFO immediately.
pub fn set_capacity(cap: usize) {
    CACHE.set_capacity(cap);
}

/// The default capacity bound ([`set_capacity`]'s restore value).
pub fn default_capacity() -> usize {
    MAX_ENTRIES
}
