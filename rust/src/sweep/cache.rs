//! In-memory memo cache for grid cells.
//!
//! Figures, ablations and the CLI repeatedly evaluate overlapping
//! (scenario × period × failure-process) cells — e.g. `headline::compute`
//! re-derives two Fig. 1 comparisons, and every bench iteration re-walks
//! the same surface. Cell evaluation is pure (seeded Monte Carlo
//! included), so results are memoised process-wide, keyed by the **exact
//! bit patterns** of every parameter that influences the output (scenario
//! floats, job kind, period, replicate count, failure process, derived
//! seed). Two cells collide only if they would compute byte-identical
//! results, so a hit is always sound.
//!
//! The cache is bounded (`MAX_ENTRIES`, coarse FIFO eviction) and can be
//! bypassed per-[`GridSpec`](super::GridSpec) or cleared/interrogated for
//! tests and benches.
//!
//! Hit/miss/eviction counters live in the telemetry registry
//! ([`crate::telemetry::registry::metrics`]) so the grid cache reports
//! through the same unified surface as every other cache; [`stats`]
//! keeps its historical `(hits, misses)` shape on top of them.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::grid::CellOutput;
use crate::telemetry::registry::metrics::{
    GRID_CACHE_EVICTIONS_TOTAL, GRID_CACHE_HITS_TOTAL, GRID_CACHE_MISSES_TOTAL,
};

/// Exact-bits cache key: every f64 is stored as `to_bits`, discrete
/// fields as tagged words (see `GridSpec::cell_key`).
pub(crate) type CellKey = Vec<u64>;

/// Default capacity bound; a full figure suite is ~10⁴ cells.
const MAX_ENTRIES: usize = 1 << 18;

struct CacheState {
    map: HashMap<CellKey, CellOutput>,
    /// Insertion order for FIFO eviction.
    order: std::collections::VecDeque<CellKey>,
    /// Current capacity bound (defaults to [`MAX_ENTRIES`]; tests and
    /// benches shrink it via [`set_capacity`] to exercise eviction).
    capacity: usize,
}

static CACHE: OnceLock<Mutex<CacheState>> = OnceLock::new();

fn cache() -> &'static Mutex<CacheState> {
    CACHE.get_or_init(|| {
        Mutex::new(CacheState {
            map: HashMap::new(),
            order: std::collections::VecDeque::new(),
            capacity: MAX_ENTRIES,
        })
    })
}

pub(crate) fn get(key: &CellKey) -> Option<CellOutput> {
    let hit = cache().lock().unwrap().map.get(key).cloned();
    match &hit {
        Some(_) => GRID_CACHE_HITS_TOTAL.inc(),
        None => GRID_CACHE_MISSES_TOTAL.inc(),
    };
    hit
}

pub(crate) fn put(key: CellKey, value: CellOutput) {
    let mut st = cache().lock().unwrap();
    if st.map.len() >= st.capacity {
        // FIFO eviction of the oldest quarter: amortised, keeps the hot
        // recent working set.
        GRID_CACHE_EVICTIONS_TOTAL.inc();
        for _ in 0..(st.capacity / 4).max(1) {
            if let Some(old) = st.order.pop_front() {
                st.map.remove(&old);
            } else {
                break;
            }
        }
    }
    if st.map.insert(key.clone(), value).is_none() {
        st.order.push_back(key);
    }
}

/// `(hits, misses)` since process start (or the last [`reset_stats`]).
pub fn stats() -> (u64, u64) {
    (GRID_CACHE_HITS_TOTAL.get(), GRID_CACHE_MISSES_TOTAL.get())
}

/// Zero the hit/miss counters (benches bracket phases with this).
pub fn reset_stats() {
    GRID_CACHE_HITS_TOTAL.reset();
    GRID_CACHE_MISSES_TOTAL.reset();
}

/// Number of memoised cells.
pub fn len() -> usize {
    cache().lock().unwrap().map.len()
}

/// Drop every memoised cell (tests; cold-start benchmarking).
pub fn clear() {
    let mut st = cache().lock().unwrap();
    st.map.clear();
    st.order.clear();
}

/// Override the capacity bound (tests/benches exercising eviction;
/// process-global — restore [`default_capacity`] afterwards). Shrinking
/// below the current size evicts FIFO immediately.
pub fn set_capacity(cap: usize) {
    let mut st = cache().lock().unwrap();
    st.capacity = cap.max(1);
    while st.map.len() > st.capacity {
        match st.order.pop_front() {
            Some(old) => {
                st.map.remove(&old);
            }
            None => break,
        }
    }
}

/// The default capacity bound ([`set_capacity`]'s restore value).
pub fn default_capacity() -> usize {
    MAX_ENTRIES
}
