//! Declarative (scenario × period × failure-process) grids.
//!
//! A [`GridSpec`] is a flat list of [`Cell`]s; [`GridSpec::evaluate`]
//! runs them on the persistent pool ([`crate::util::pool::ThreadPool`]),
//! consults the memo cache ([`super::cache`]), and returns one
//! [`CellResult`] per cell **in cell order** — so callers zip results
//! with whatever axes they built the grid from.
//!
//! The cell jobs cover every consumer in the crate:
//!
//! * [`CellJob::Model`] — closed-form `T_final`/`E_final` at a period
//!   (the CLI `sweep` path).
//! * [`CellJob::Compare`] — the AlgoT-vs-AlgoE [`Comparison`] every
//!   figure plots; out-of-domain scenarios yield `None` (the Fig. 3
//!   "clamped" tail).
//! * [`CellJob::Sim`] — seeded Monte-Carlo estimation, optionally under a
//!   non-paper [`FailureProcess`] (per-node Weibull platforms etc.).
//! * [`CellJob::Frontier`] — the time–energy Pareto frontier between the
//!   two optima ([`crate::pareto`]), under a selectable objective-model
//!   [`Backend`] (part of the cache key).
//! * [`CellJob::AdaptiveRun`] — Monte-Carlo of the *adaptive* simulator
//!   ([`crate::sim::adaptive`]): an online controller re-estimates
//!   `(C, R, μ)` along each sample path and re-reads its
//!   [`PeriodPolicy`] — policy comparisons across scenario grids run
//!   parallel and memo-cached like everything else.
//! * [`CellJob::DriftRun`] — the adaptive simulator on a *drifting*
//!   environment ([`crate::drift`]): each cell runs the estimating
//!   controller **and** its clairvoyant oracle twin on the same seeds,
//!   and reports tracking lag plus the oracle-relative waste/energy
//!   regret ([`DriftSummary`]). The drift schedule and the controller
//!   knobs (EWMA α, hysteresis band) are part of the cache key; the
//!   seed deliberately ignores the controller knobs so an α × band
//!   sweep is a paired (common-random-numbers) comparison.
//!
//! # Seeding
//!
//! Each simulated cell derives its seed by hashing the spec's `base_seed`
//! with the cell's full parameter bit pattern (`cell_seed`). Replicate
//! `i` inside the cell then uses `cell_seed + i`, exactly like
//! [`monte_carlo`]. The derivation depends only on *what* the cell is —
//! never on its position in the grid, the thread count, or the steal
//! schedule — so results are byte-identical across thread counts and
//! stable when a grid is re-arranged or filtered.

use crate::coordinator::policy::PeriodPolicy;
use crate::drift::DriftProcess;
use crate::model::backend::Backend;
use crate::model::params::{ModelError, Scenario};
use crate::model::ratios::{compare, Comparison};
use crate::model::{e_final, t_final};
use crate::pareto::frontier::FrontierSummary;
use crate::pareto::KneeMethod;
use crate::sim::adaptive::{
    adaptive_monte_carlo, adaptive_monte_carlo_with, AdaptiveMonteCarloResult, AdaptiveSimConfig,
    AdaptiveSimulator,
};
use crate::sim::runner::{monte_carlo, MonteCarloResult};
use crate::sim::{FailureProcess, SimConfig};
use crate::util::pool::ThreadPool;
use crate::util::stats::ConfidenceLevel;

use super::cache;
use super::cache::CellKey;

/// Bump when the evaluation semantics change (invalidates memo entries).
/// v2: the objective-model backend joined the Frontier cell and the
/// policy encoding. v3: the drift layer joined the cell space (the
/// `DriftRun` job, drifting failure processes in the key).
/// v4: tiered storage joined the cell space (the scenario key grew its
/// tier extension words; `Sim` cells gained drain queues).
const KEY_VERSION: u64 = 4;

/// Seed derivation stays pinned at the v3 word: a seed key only needs
/// to be *unique per environment*, and the sample paths derived from it
/// are pinned by golden simulated figures. Scalar cells therefore keep
/// their exact pre-tier seeds; tiered cells still get distinct seeds
/// through the scenario's tier extension words.
const SEED_KEY_VERSION: u64 = 3;

/// What to compute for one cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CellJob {
    /// Closed-form `T_final`/`E_final` at `period`.
    Model { period: f64 },
    /// AlgoT-vs-AlgoE comparison (periods chosen by the policies).
    Compare,
    /// Monte-Carlo estimate at `period` over `replicates` sample paths.
    Sim { period: f64, replicates: usize, failures_during_recovery: bool },
    /// Time–energy Pareto frontier sampled at `points` periods between
    /// the two optima of `backend`'s objectives ([`crate::pareto`]).
    Frontier { points: usize, backend: Backend },
    /// Monte-Carlo estimate of `replicates` *adaptive* sample paths:
    /// the period is re-estimated online by an
    /// [`AdaptiveController`](crate::coordinator::AdaptiveController)
    /// running `policy`, seeded with the scenario's μ as its prior
    /// ([`crate::sim::adaptive`]).
    AdaptiveRun { policy: PeriodPolicy, replicates: usize, failures_during_recovery: bool },
    /// [`CellJob::AdaptiveRun`] on a *drifting* environment: the true
    /// `(C, R, μ, P_IO)` follow `drift`, failures arrive from the
    /// thinned non-homogeneous sampler (unless the cell supplies its
    /// own [`Cell::failure`], which overrides the matched sampler — a
    /// deliberate escape hatch for e.g. bursty per-node Weibull
    /// failures on a drifting cost environment), and the controller
    /// runs with the given EWMA smoothing and hysteresis band. Each
    /// cell also runs the clairvoyant-oracle twin on the same seeds
    /// and reports the regret ([`DriftSummary`]). With `drift =
    /// DriftProcess::Stationary` and the default knobs the adaptive
    /// half is **bit-identical** to `AdaptiveRun` at the same seed.
    DriftRun {
        policy: PeriodPolicy,
        replicates: usize,
        failures_during_recovery: bool,
        drift: DriftProcess,
        /// Controller C/R EWMA smoothing factor (`0.3` = the
        /// `AdaptiveRun` default).
        alpha: f64,
        /// Controller period-space hysteresis band (`0.05` = the
        /// `AdaptiveRun` default).
        hysteresis: f64,
    },
}

/// One grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub scenario: Scenario,
    /// `None` ⇒ the paper's aggregate-exponential process at the
    /// scenario's `μ`. Only consulted by [`CellJob::Sim`].
    pub failure: Option<FailureProcess>,
    pub job: CellJob,
}

/// Compact, cacheable Monte-Carlo summary of one simulated cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimSummary {
    pub replicates: usize,
    pub makespan_mean: f64,
    pub makespan_ci95_half: f64,
    pub energy_mean: f64,
    pub energy_ci95_half: f64,
    pub failures_mean: f64,
    pub checkpoints_mean: f64,
    pub work_lost_mean: f64,
}

impl SimSummary {
    pub fn from_mc(mc: &MonteCarloResult) -> Self {
        SimSummary {
            replicates: mc.replicates,
            makespan_mean: mc.makespan.mean(),
            makespan_ci95_half: mc.makespan.ci_half_width(ConfidenceLevel::P95),
            energy_mean: mc.energy.mean(),
            energy_ci95_half: mc.energy.ci_half_width(ConfidenceLevel::P95),
            failures_mean: mc.failures.mean(),
            checkpoints_mean: mc.checkpoints.mean(),
            work_lost_mean: mc.work_lost.mean(),
        }
    }

    /// `(lo, hi)` 95% confidence interval of the mean makespan.
    pub fn makespan_ci95(&self) -> (f64, f64) {
        (self.makespan_mean - self.makespan_ci95_half, self.makespan_mean + self.makespan_ci95_half)
    }

    /// `(lo, hi)` 95% confidence interval of the mean energy.
    pub fn energy_ci95(&self) -> (f64, f64) {
        (self.energy_mean - self.energy_ci95_half, self.energy_mean + self.energy_ci95_half)
    }
}

/// Compact, cacheable Monte-Carlo summary of one adaptive cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveSummary {
    pub replicates: usize,
    pub makespan_mean: f64,
    pub makespan_ci95_half: f64,
    pub energy_mean: f64,
    pub energy_ci95_half: f64,
    pub failures_mean: f64,
    pub checkpoints_mean: f64,
    pub work_lost_mean: f64,
    /// Mean number of applied-period changes per run (hysteresis-band
    /// crossings).
    pub period_updates_mean: f64,
    /// Mean period in force at the end of a run.
    pub final_period_mean: f64,
    /// Mean per-run tracking lag against the instantaneous policy
    /// period on the true scenario
    /// ([`AdaptiveRunResult::tracking_lag_pct`](crate::sim::adaptive::AdaptiveRunResult)).
    pub tracking_lag_pct_mean: f64,
    /// Mean per-run μ-noise-cancelled drift lag
    /// ([`AdaptiveRunResult::drift_lag_pct`](crate::sim::adaptive::AdaptiveRunResult))
    /// — the component the EWMA α controls.
    pub drift_lag_pct_mean: f64,
}

impl AdaptiveSummary {
    pub fn from_mc(mc: &AdaptiveMonteCarloResult) -> Self {
        AdaptiveSummary {
            replicates: mc.replicates,
            makespan_mean: mc.makespan.mean(),
            makespan_ci95_half: mc.makespan.ci_half_width(ConfidenceLevel::P95),
            energy_mean: mc.energy.mean(),
            energy_ci95_half: mc.energy.ci_half_width(ConfidenceLevel::P95),
            failures_mean: mc.failures.mean(),
            checkpoints_mean: mc.checkpoints.mean(),
            work_lost_mean: mc.work_lost.mean(),
            period_updates_mean: mc.period_updates.mean(),
            final_period_mean: mc.final_period.mean(),
            tracking_lag_pct_mean: mc.tracking_lag.mean(),
            drift_lag_pct_mean: mc.drift_lag.mean(),
        }
    }
}

/// Compact, cacheable summary of one drift cell: the estimating
/// controller's Monte-Carlo summary plus the clairvoyant-oracle twin
/// (same seeds) and the regret between them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftSummary {
    /// The estimating controller's runs.
    pub adaptive: AdaptiveSummary,
    /// Mean makespan of the oracle twin (period re-read from the true
    /// instantaneous scenario at the same decision points, same seeds).
    pub oracle_makespan_mean: f64,
    /// Mean energy of the oracle twin.
    pub oracle_energy_mean: f64,
    /// `(makespan − oracle_makespan)/T_base · 100`: the waste the
    /// controller's estimation lag costs over clairvoyance. Near the
    /// knee the frontier is flat to first order, so this is small and
    /// can carry either sign (a low-lagging period trades time against
    /// energy).
    pub waste_regret_pct: f64,
    /// `(energy − oracle_energy)/(T_base·(P_Static+P_Cal)) · 100`: the
    /// energy-side twin of [`Self::waste_regret_pct`], normalised to
    /// the failure-free, checkpoint-free floor.
    pub energy_regret_pct: f64,
}

/// The outcome of one cell.
#[derive(Debug, Clone, PartialEq)]
pub enum CellOutput {
    Model { t_final: f64, e_final: f64 },
    /// `None` when the scenario left the model's domain (both strategies
    /// collapse to `T = C`; figures report the cell as clamped).
    Compare(Option<Comparison>),
    Sim(SimSummary),
    /// The frontier, or the [`ModelError`] explaining why the scenario
    /// has none — the same out-of-domain clamp regime as `Compare`,
    /// with the reason preserved so family/CLI consumers can surface it
    /// instead of silently dropping the row.
    Frontier(Result<FrontierSummary, ModelError>),
    /// `None` when the scenario has no feasible period at all (the same
    /// clamp regime as `Compare`/`Frontier`).
    Adaptive(Option<AdaptiveSummary>),
    /// `None` when the scenario has no feasible period or the drift
    /// schedule drives it out of the model's domain (the
    /// [`EnvTrajectory`](crate::drift::EnvTrajectory) worst-corner
    /// gate).
    Drift(Option<DriftSummary>),
}

impl CellOutput {
    /// The comparison, when this was a [`CellJob::Compare`] cell.
    pub fn comparison(&self) -> Option<&Comparison> {
        match self {
            CellOutput::Compare(Some(c)) => Some(c),
            _ => None,
        }
    }

    /// The Monte-Carlo summary, when this was a [`CellJob::Sim`] cell.
    pub fn sim(&self) -> Option<&SimSummary> {
        match self {
            CellOutput::Sim(s) => Some(s),
            _ => None,
        }
    }

    /// The frontier, when this was an in-domain [`CellJob::Frontier`]
    /// cell.
    pub fn frontier(&self) -> Option<&FrontierSummary> {
        match self {
            CellOutput::Frontier(Ok(f)) => Some(f),
            _ => None,
        }
    }

    /// The adaptive summary, when this was a [`CellJob::AdaptiveRun`]
    /// cell.
    pub fn adaptive(&self) -> Option<&AdaptiveSummary> {
        match self {
            CellOutput::Adaptive(Some(a)) => Some(a),
            _ => None,
        }
    }

    /// The drift summary, when this was an in-domain
    /// [`CellJob::DriftRun`] cell.
    pub fn drift(&self) -> Option<&DriftSummary> {
        match self {
            CellOutput::Drift(Some(d)) => Some(d),
            _ => None,
        }
    }
}

/// One evaluated cell: the cell, the seed it derived, and its output.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub cell: Cell,
    /// Derived per-cell seed (0 for pure model/compare cells).
    pub seed: u64,
    pub output: CellOutput,
}

/// A declarative batch of cells.
#[derive(Debug, Clone, PartialEq)]
pub struct GridSpec {
    cells: Vec<Cell>,
    /// Seed every simulated cell derives from.
    pub base_seed: u64,
    /// Consult/populate the process-wide memo cache (default on).
    pub use_cache: bool,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec::new(1)
    }
}

impl GridSpec {
    pub fn new(base_seed: u64) -> Self {
        GridSpec { cells: Vec::new(), base_seed, use_cache: true }
    }

    /// Disable the memo cache for this spec (benchmarks, soak tests).
    pub fn without_cache(mut self) -> Self {
        self.use_cache = false;
        self
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    pub fn push(&mut self, cell: Cell) -> &mut Self {
        self.cells.push(cell);
        self
    }

    /// Append an AlgoT-vs-AlgoE comparison cell.
    pub fn push_compare(&mut self, scenario: Scenario) -> &mut Self {
        self.push(Cell { scenario, failure: None, job: CellJob::Compare })
    }

    /// Append a closed-form evaluation cell.
    pub fn push_model(&mut self, scenario: Scenario, period: f64) -> &mut Self {
        self.push(Cell { scenario, failure: None, job: CellJob::Model { period } })
    }

    /// Append a Monte-Carlo cell (paper failure process).
    pub fn push_sim(&mut self, scenario: Scenario, period: f64, replicates: usize) -> &mut Self {
        self.push(Cell {
            scenario,
            failure: None,
            job: CellJob::Sim { period, replicates, failures_during_recovery: true },
        })
    }

    /// Append a Pareto-frontier cell (`points` samples between the
    /// first-order optima).
    pub fn push_frontier(&mut self, scenario: Scenario, points: usize) -> &mut Self {
        self.push_frontier_with(scenario, points, Backend::FirstOrder)
    }

    /// Append a Pareto-frontier cell under an explicit objective-model
    /// backend (part of the cache key and, were the cell simulated, the
    /// seed derivation).
    pub fn push_frontier_with(
        &mut self,
        scenario: Scenario,
        points: usize,
        backend: Backend,
    ) -> &mut Self {
        self.push(Cell { scenario, failure: None, job: CellJob::Frontier { points, backend } })
    }

    /// Append an adaptive-controller Monte-Carlo cell (paper failure
    /// process).
    pub fn push_adaptive(
        &mut self,
        scenario: Scenario,
        policy: PeriodPolicy,
        replicates: usize,
    ) -> &mut Self {
        self.push(Cell {
            scenario,
            failure: None,
            job: CellJob::AdaptiveRun { policy, replicates, failures_during_recovery: true },
        })
    }

    /// Append a drift cell (paper base failure process lifted onto the
    /// trajectory's thinned sampler; see [`CellJob::DriftRun`]).
    pub fn push_drift(
        &mut self,
        scenario: Scenario,
        policy: PeriodPolicy,
        replicates: usize,
        drift: DriftProcess,
        alpha: f64,
        hysteresis: f64,
    ) -> &mut Self {
        self.push(Cell {
            scenario,
            failure: None,
            job: CellJob::DriftRun {
                policy,
                replicates,
                failures_during_recovery: true,
                drift,
                alpha,
                hysteresis,
            },
        })
    }

    /// Comparison grid over a scenario family (the figures' shape).
    pub fn compare_all(scenarios: impl IntoIterator<Item = Scenario>, base_seed: u64) -> Self {
        let mut spec = GridSpec::new(base_seed);
        for s in scenarios {
            spec.push_compare(s);
        }
        spec
    }

    /// Closed-form sweep of one scenario over a period grid (CLI `sweep`).
    pub fn model_sweep(scenario: Scenario, periods: &[f64], base_seed: u64) -> Self {
        let mut spec = GridSpec::new(base_seed);
        for &t in periods {
            spec.push_model(scenario, t);
        }
        spec
    }

    /// Exact-bits cache key for a cell (includes `base_seed` only where
    /// it matters — simulated cells).
    pub(crate) fn cell_key(&self, cell: &Cell) -> CellKey {
        self.key_for(cell, false)
    }

    /// Shared key builder. `for_seed` builds the *seed* key: identical
    /// to the cache key except that a [`CellJob::DriftRun`]'s controller
    /// knobs (EWMA α, hysteresis band) are omitted — an α × band sweep
    /// over one drift schedule then reuses the same sample paths
    /// (common random numbers), which is what makes the drift figure's
    /// "tracking lag decreases in α" comparison a paired one instead of
    /// noise. Environment parameters (scenario, drift, failure process,
    /// policy, replicate count) always enter both keys.
    fn key_for(&self, cell: &Cell, for_seed: bool) -> CellKey {
        let mut k = Vec::with_capacity(24);
        k.push(if for_seed { SEED_KEY_VERSION } else { KEY_VERSION });
        k.extend(cell.scenario.key_words());
        match &cell.failure {
            None => k.push(0),
            Some(FailureProcess::Exponential { mtbf }) => {
                k.push(1);
                k.push(mtbf.to_bits());
            }
            Some(FailureProcess::PerNodeExponential { n, mtbf_ind }) => {
                k.push(2);
                k.push(*n as u64);
                k.push(mtbf_ind.to_bits());
            }
            Some(FailureProcess::PerNodeWeibull { n, shape, scale_ind }) => {
                k.push(3);
                k.push(*n as u64);
                k.push(shape.to_bits());
                k.push(scale_ind.to_bits());
            }
            Some(FailureProcess::DriftingExponential { trajectory }) => {
                k.push(4);
                k.extend_from_slice(&trajectory.key_words());
            }
        }
        match cell.job {
            CellJob::Model { period } => {
                k.push(10);
                k.push(period.to_bits());
            }
            CellJob::Compare => k.push(11),
            CellJob::Sim { period, replicates, failures_during_recovery } => {
                k.push(12);
                k.push(period.to_bits());
                k.push(replicates as u64);
                k.push(u64::from(failures_during_recovery));
                k.push(self.base_seed);
            }
            CellJob::Frontier { points, backend } => {
                k.push(13);
                k.push(points as u64);
                k.push(backend.key_word());
            }
            CellJob::AdaptiveRun { policy, replicates, failures_during_recovery } => {
                k.push(14);
                k.extend_from_slice(&policy_key(policy));
                k.push(replicates as u64);
                k.push(u64::from(failures_during_recovery));
                k.push(self.base_seed);
            }
            CellJob::DriftRun {
                policy,
                replicates,
                failures_during_recovery,
                drift,
                alpha,
                hysteresis,
            } => {
                k.push(15);
                k.extend_from_slice(&policy_key(policy));
                k.push(replicates as u64);
                k.push(u64::from(failures_during_recovery));
                k.extend_from_slice(&drift.key_words());
                if !for_seed {
                    k.push(alpha.to_bits());
                    k.push(hysteresis.to_bits());
                }
                k.push(self.base_seed);
            }
        }
        k
    }

    /// The seed a simulated ([`CellJob::Sim`] / [`CellJob::AdaptiveRun`]
    /// / [`CellJob::DriftRun`]) cell derives (position-independent:
    /// hashes `base_seed` with the cell's parameter bits; see
    /// [`Self::key_for`] for the `DriftRun` knob exclusion).
    pub fn cell_seed(&self, cell: &Cell) -> u64 {
        match cell.job {
            CellJob::Sim { .. } | CellJob::AdaptiveRun { .. } | CellJob::DriftRun { .. } => {
                derive_seed(&self.key_for(cell, true))
            }
            _ => 0,
        }
    }

    /// Evaluate every cell on the persistent pool. Results are in cell
    /// order and independent of the thread count.
    pub fn evaluate(&self) -> Vec<CellResult> {
        let outputs: Vec<CellOutput> = ThreadPool::global().map(self.cells.len(), |i| {
            let cell = &self.cells[i];
            let key = self.cell_key(cell);
            if self.use_cache {
                if let Some(hit) = cache::get(&key) {
                    return hit;
                }
            }
            let out = {
                // Cache hits skip the span: the histogram measures cell
                // *evaluation*, not lookup.
                let _span = crate::telemetry::Span::start(
                    &crate::telemetry::registry::metrics::GRID_CELL_NS,
                );
                eval_cell(cell, self.cell_seed(cell))
            };
            if self.use_cache {
                cache::put(key, out.clone());
            }
            out
        });
        self.cells
            .iter()
            .zip(outputs)
            .map(|(cell, output)| CellResult {
                cell: cell.clone(),
                seed: self.cell_seed(cell),
                output,
            })
            .collect()
    }
}

fn eval_cell(cell: &Cell, seed: u64) -> CellOutput {
    match cell.job {
        CellJob::Model { period } => CellOutput::Model {
            t_final: t_final(&cell.scenario, period),
            e_final: e_final(&cell.scenario, period),
        },
        CellJob::Compare => CellOutput::Compare(compare(&cell.scenario).ok()),
        CellJob::Sim { period, replicates, failures_during_recovery } => {
            let cfg = SimConfig {
                scenario: cell.scenario,
                period,
                failure: cell
                    .failure
                    .clone()
                    .unwrap_or(FailureProcess::Exponential { mtbf: cell.scenario.mu }),
                failures_during_recovery,
            };
            // `monte_carlo` degrades to an inline loop inside pool
            // workers, so a grid of Sim cells parallelises over cells and
            // a single Sim cell parallelises over replicates.
            let mc = monte_carlo(&cfg, replicates, seed, replicates);
            CellOutput::Sim(SimSummary::from_mc(&mc))
        }
        CellJob::Frontier { points, backend } => {
            CellOutput::Frontier(FrontierSummary::compute(&cell.scenario, points, backend))
        }
        CellJob::AdaptiveRun { policy, replicates, failures_during_recovery } => {
            if cell.scenario.clamp_period(cell.scenario.min_period()).is_err() {
                return CellOutput::Adaptive(None);
            }
            let mut cfg = AdaptiveSimConfig::paper(cell.scenario, policy);
            if let Some(f) = cell.failure.clone() {
                cfg.failure = f;
            }
            cfg.failures_during_recovery = failures_during_recovery;
            let mc = adaptive_monte_carlo(&cfg, replicates, seed, replicates);
            CellOutput::Adaptive(Some(AdaptiveSummary::from_mc(&mc)))
        }
        CellJob::DriftRun {
            policy,
            replicates,
            failures_during_recovery,
            drift,
            alpha,
            hysteresis,
        } => {
            if cell.scenario.clamp_period(cell.scenario.min_period()).is_err() {
                return CellOutput::Drift(None);
            }
            // The worst-corner gate: a schedule that drives the
            // scenario out of the model's domain clamps the cell, like
            // every other out-of-domain regime here.
            let mut cfg = match AdaptiveSimConfig::paper_drifting(cell.scenario, policy, drift)
            {
                Ok(cfg) => cfg,
                Err(_) => return CellOutput::Drift(None),
            };
            if let Some(f) = cell.failure.clone() {
                cfg.failure = f;
            }
            cfg.failures_during_recovery = failures_during_recovery;
            cfg.alpha = alpha;
            cfg.hysteresis = hysteresis;
            // Build the simulator (and its sampled `EnvTrajectory`)
            // once per cell: the clairvoyant twin shares the identical
            // trajectory instead of re-sampling it from the config.
            let sim = AdaptiveSimulator::new(cfg);
            let mc = adaptive_monte_carlo_with(&sim, replicates, seed, replicates);
            // The clairvoyant twin: same seeds (and, for μ-stationary
            // schedules, bit-identical failure draws), period re-read
            // from the true instantaneous scenario.
            let omc = adaptive_monte_carlo_with(&sim.oracle_twin(), replicates, seed, replicates);
            let s = &cell.scenario;
            let e_floor = s.t_base * (s.power.p_static + s.power.p_cal);
            CellOutput::Drift(Some(DriftSummary {
                adaptive: AdaptiveSummary::from_mc(&mc),
                oracle_makespan_mean: omc.makespan.mean(),
                oracle_energy_mean: omc.energy.mean(),
                waste_regret_pct: (mc.makespan.mean() - omc.makespan.mean()) / s.t_base
                    * 100.0,
                energy_regret_pct: (mc.energy.mean() - omc.energy.mean()) / e_floor * 100.0,
            }))
        }
    }
}

/// Stable `[tag, parameter-bits, backend]` encoding of a
/// [`PeriodPolicy`] for cache keys and seed derivation. The backend
/// word keeps a first-order and an exact run of the same policy from
/// aliasing in the cache (and gives them distinct seeds). The serve
/// layer ([`crate::serve`]) reuses this encoding for its query dedup
/// keys, so a policy is keyed identically everywhere in the process.
pub(crate) fn policy_key(p: PeriodPolicy) -> [u64; 3] {
    let backend_word = p.backend().map(|b| b.key_word()).unwrap_or(0);
    match p {
        PeriodPolicy::AlgoT => [0, 0, 0],
        PeriodPolicy::AlgoE => [1, 0, 0],
        PeriodPolicy::Young => [2, 0, 0],
        PeriodPolicy::Daly => [3, 0, 0],
        PeriodPolicy::Fixed(t) => [4, t.to_bits(), 0],
        PeriodPolicy::Knee { method: KneeMethod::MaxDistanceToChord, .. } => {
            [5, 0, backend_word]
        }
        PeriodPolicy::Knee { method: KneeMethod::MaxCurvature, .. } => [5, 1, backend_word],
        PeriodPolicy::EnergyBudget { max_time_overhead, .. } => {
            [6, max_time_overhead.to_bits(), backend_word]
        }
        PeriodPolicy::TimeBudget { max_energy_overhead, .. } => {
            [7, max_energy_overhead.to_bits(), backend_word]
        }
    }
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

pub(crate) fn derive_seed(key: &[u64]) -> u64 {
    let mut h = 0x517CC1B727220A95u64;
    for &w in key {
        h = splitmix64(h ^ w);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;
    use crate::model::{t_energy_opt, t_time_opt};
    use crate::util::stats::rel_err;

    fn scenario() -> Scenario {
        fig1_scenario(300.0, 5.5)
    }

    #[test]
    fn model_cells_match_direct_evaluation() {
        let s = scenario();
        let periods = [40.0, 80.0, 160.0];
        let spec = GridSpec::model_sweep(s, &periods, 1).without_cache();
        let results = spec.evaluate();
        assert_eq!(results.len(), 3);
        for (r, &t) in results.iter().zip(&periods) {
            match r.output {
                CellOutput::Model { t_final: tf, e_final: ef } => {
                    assert_eq!(tf, t_final(&s, t));
                    assert_eq!(ef, e_final(&s, t));
                }
                ref other => panic!("unexpected output {other:?}"),
            }
        }
    }

    #[test]
    fn compare_cells_match_direct_compare() {
        let s = scenario();
        let spec = GridSpec::compare_all([s], 1).without_cache();
        let results = spec.evaluate();
        let cmp = results[0].output.comparison().expect("in domain");
        let direct = compare(&s).unwrap();
        assert_eq!(*cmp, direct);
    }

    #[test]
    fn compare_out_of_domain_is_none_not_panic() {
        // mu barely above the overheads: compare() errors => None.
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        // b > 0 requires mu > 16; pick mu where construction succeeds but
        // clamping fails (C >= 2*mu*b): mu = 17 => 2*mu*b = 2.0 < C = 10.
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        let spec = GridSpec::compare_all([s], 1).without_cache();
        let out = &spec.evaluate()[0].output;
        assert_eq!(out.comparison(), None);
        assert!(matches!(out, CellOutput::Compare(None)));
    }

    #[test]
    fn sim_cells_match_monte_carlo_with_derived_seed() {
        let s = scenario();
        let t = t_time_opt(&s).unwrap();
        let mut spec = GridSpec::new(42);
        spec.push_sim(s, t, 64);
        let spec = spec.without_cache();
        let seed = spec.cell_seed(&spec.cells()[0]);
        let results = spec.evaluate();
        let summary = results[0].output.sim().unwrap();
        assert_eq!(results[0].seed, seed);

        let mc = monte_carlo(&SimConfig::paper(s, t), 64, seed, 8);
        assert_eq!(summary.makespan_mean, mc.makespan.mean());
        assert_eq!(summary.energy_mean, mc.energy.mean());
        assert_eq!(summary.replicates, 64);
    }

    #[test]
    fn seeds_depend_on_cell_not_position() {
        let s = scenario();
        let t = t_time_opt(&s).unwrap();
        let te = t_energy_opt(&s).unwrap();
        let mut a = GridSpec::new(7);
        a.push_sim(s, t, 32).push_sim(s, te, 32);
        let mut b = GridSpec::new(7);
        b.push_sim(s, te, 32).push_sim(s, t, 32);
        // Same cells, swapped order: per-cell seeds are identical.
        assert_eq!(a.cell_seed(&a.cells()[0]), b.cell_seed(&b.cells()[1]));
        assert_eq!(a.cell_seed(&a.cells()[1]), b.cell_seed(&b.cells()[0]));
        // Different base seed => different cell seeds.
        let mut c = GridSpec::new(8);
        c.push_sim(s, t, 32);
        assert_ne!(a.cell_seed(&a.cells()[0]), c.cell_seed(&c.cells()[0]));
    }

    #[test]
    fn cache_hits_return_identical_outputs() {
        let s = fig1_scenario(120.0, 7.0);
        let t = t_time_opt(&s).unwrap();
        let mut spec = GridSpec::new(0xCACE);
        spec.push_sim(s, t, 48);
        spec.push_compare(s);

        let first = spec.evaluate();
        let (h_before, _) = cache::stats();
        let second = spec.evaluate();
        let (h_after, _) = cache::stats();
        // Counters are process-global and other tests run concurrently,
        // so assert only the delta our two cells must contribute.
        assert!(h_after - h_before >= 2, "expected cache hits on re-evaluation");
        assert_eq!(first, second);
    }

    #[test]
    fn weibull_failure_cells_run_and_stay_sane() {
        let s = scenario();
        let t = t_time_opt(&s).unwrap();
        let n = 100usize;
        let shape = 0.7;
        let scale = 300.0 * n as f64 / crate::sim::failure::gamma(1.0 + 1.0 / shape);
        let mut spec = GridSpec::new(3);
        spec.push(Cell {
            scenario: s,
            failure: Some(FailureProcess::PerNodeWeibull { n, shape, scale_ind: scale }),
            job: CellJob::Sim { period: t, replicates: 64, failures_during_recovery: true },
        });
        let out = spec.without_cache().evaluate();
        let sim = out[0].output.sim().unwrap();
        // Same long-run MTBF: the exponential model keeps the order of
        // magnitude even under bursty per-node Weibull failures.
        assert!(rel_err(sim.makespan_mean, t_final(&s, t)) < 0.2, "{}", sim.makespan_mean);
    }

    #[test]
    fn mixed_grid_evaluates_every_job_kind() {
        let s = scenario();
        let t = t_time_opt(&s).unwrap();
        let mut spec = GridSpec::new(5);
        spec.push_model(s, t).push_compare(s).push_sim(s, t, 16).push_frontier(s, 9);
        let results = spec.without_cache().evaluate();
        assert!(matches!(results[0].output, CellOutput::Model { .. }));
        assert!(matches!(results[1].output, CellOutput::Compare(Some(_))));
        assert!(matches!(results[2].output, CellOutput::Sim(_)));
        assert!(matches!(results[3].output, CellOutput::Frontier(Ok(_))));
    }

    #[test]
    fn frontier_cells_match_direct_computation_and_memoise() {
        let s = scenario();
        let mut spec = GridSpec::new(1);
        spec.push_frontier(s, 17);
        let direct = FrontierSummary::compute(&s, 17, Backend::FirstOrder).unwrap();
        let first = spec.evaluate();
        assert_eq!(first[0].output.frontier().unwrap(), &direct);
        // Pure model cell: no seed derived.
        assert_eq!(first[0].seed, 0);
        let (h_before, _) = cache::stats();
        let second = spec.evaluate();
        let (h_after, _) = cache::stats();
        assert!(h_after - h_before >= 1, "expected a frontier cache hit");
        assert_eq!(first, second);
        // A different sampling density is a different cell.
        let mut other = GridSpec::new(1);
        other.push_frontier(s, 33);
        assert_ne!(spec.cell_key(&spec.cells()[0]), other.cell_key(&other.cells()[0]));
        // And so is a different objective backend.
        let mut exact = GridSpec::new(1);
        exact.push_frontier_with(s, 17, Backend::Exact(crate::model::RecoveryModel::Ideal));
        assert_ne!(spec.cell_key(&spec.cells()[0]), exact.cell_key(&exact.cells()[0]));
    }

    #[test]
    fn exact_frontier_cells_match_direct_computation() {
        let s = fig1_scenario(120.0, 5.5);
        let backend = Backend::Exact(crate::model::RecoveryModel::Restarting);
        let mut spec = GridSpec::new(1);
        spec.push_frontier_with(s, 17, backend);
        let direct = FrontierSummary::compute(&s, 17, backend).unwrap();
        let out = spec.evaluate();
        assert_eq!(out[0].output.frontier().unwrap(), &direct);
        assert_eq!(out[0].output.frontier().unwrap().backend, backend);
    }

    #[test]
    fn frontier_out_of_domain_carries_the_error() {
        // Same breakdown scenario as the Compare clamp test; the cell
        // preserves the ModelError instead of flattening it to None.
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        let mut spec = GridSpec::new(1);
        spec.push_frontier(s, 9);
        let out = spec.without_cache().evaluate();
        assert!(matches!(out[0].output, CellOutput::Frontier(Err(ModelError::OutOfDomain(_)))));
        assert_eq!(out[0].output.frontier(), None);
    }

    #[test]
    fn adaptive_cells_match_direct_monte_carlo_with_derived_seed() {
        let s = scenario();
        let policy = PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend: Backend::FirstOrder,
        };
        let mut spec = GridSpec::new(77);
        spec.push_adaptive(s, policy, 32);
        let spec = spec.without_cache();
        let seed = spec.cell_seed(&spec.cells()[0]);
        assert_ne!(seed, 0, "adaptive cells derive a seed");
        let results = spec.evaluate();
        assert_eq!(results[0].seed, seed);
        let summary = results[0].output.adaptive().unwrap();

        let cfg = AdaptiveSimConfig::paper(s, policy);
        let mc = adaptive_monte_carlo(&cfg, 32, seed, 1);
        assert_eq!(summary.makespan_mean.to_bits(), mc.makespan.mean().to_bits());
        assert_eq!(summary.energy_mean.to_bits(), mc.energy.mean().to_bits());
        assert_eq!(summary.final_period_mean.to_bits(), mc.final_period.mean().to_bits());
        assert_eq!(summary.replicates, 32);
    }

    #[test]
    fn adaptive_cell_keys_distinguish_policies() {
        let s = scenario();
        let mut a = GridSpec::new(1);
        a.push_adaptive(s, PeriodPolicy::AlgoT, 32);
        let mut b = GridSpec::new(1);
        b.push_adaptive(s, PeriodPolicy::AlgoE, 32);
        assert_ne!(a.cell_key(&a.cells()[0]), b.cell_key(&b.cells()[0]));
        assert_ne!(a.cell_seed(&a.cells()[0]), b.cell_seed(&b.cells()[0]));
        let knee = |backend| PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend,
        };
        let mut c = GridSpec::new(1);
        c.push_adaptive(s, knee(Backend::FirstOrder), 32);
        let mut d = GridSpec::new(1);
        d.push_adaptive(
            s,
            PeriodPolicy::Knee {
                method: KneeMethod::MaxCurvature,
                backend: Backend::FirstOrder,
            },
            32,
        );
        assert_ne!(c.cell_key(&c.cells()[0]), d.cell_key(&d.cells()[0]));
        // Budget parameter is part of the key.
        let fo = Backend::FirstOrder;
        let mut e = GridSpec::new(1);
        e.push_adaptive(s, PeriodPolicy::EnergyBudget { max_time_overhead: 2.0, backend: fo }, 32);
        let mut f = GridSpec::new(1);
        f.push_adaptive(s, PeriodPolicy::EnergyBudget { max_time_overhead: 5.0, backend: fo }, 32);
        assert_ne!(e.cell_key(&e.cells()[0]), f.cell_key(&f.cells()[0]));
        // And so is the objective backend of a frontier-aware policy.
        let mut g = GridSpec::new(1);
        g.push_adaptive(s, knee(Backend::Exact(crate::model::RecoveryModel::Ideal)), 32);
        assert_ne!(c.cell_key(&c.cells()[0]), g.cell_key(&g.cells()[0]));
        assert_ne!(c.cell_seed(&c.cells()[0]), g.cell_seed(&g.cells()[0]));
    }

    fn knee() -> PeriodPolicy {
        PeriodPolicy::Knee {
            method: KneeMethod::MaxDistanceToChord,
            backend: Backend::FirstOrder,
        }
    }

    fn io_ramp() -> crate::drift::DriftProcess {
        crate::drift::DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 5000.0,
            to: crate::drift::DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 2.0 },
        }
    }

    #[test]
    fn stationary_drift_cells_match_adaptive_run_bitwise() {
        // The grid-level zero-regression guarantee: a DriftRun cell
        // with a Stationary schedule and the AdaptiveRun defaults
        // produces the same adaptive summary fields as the plain
        // adaptive Monte-Carlo at the drift cell's own seed.
        let s = scenario();
        let mut spec = GridSpec::new(91);
        spec.push_drift(s, knee(), 24, DriftProcess::Stationary, 0.3, 0.05);
        let spec = spec.without_cache();
        let seed = spec.cell_seed(&spec.cells()[0]);
        assert_ne!(seed, 0);
        let results = spec.evaluate();
        let sum = results[0].output.drift().expect("in domain");

        let cfg = AdaptiveSimConfig::paper(s, knee());
        let direct = adaptive_monte_carlo(&cfg, 24, seed, 1);
        assert_eq!(sum.adaptive.makespan_mean.to_bits(), direct.makespan.mean().to_bits());
        assert_eq!(sum.adaptive.energy_mean.to_bits(), direct.energy.mean().to_bits());
        assert_eq!(
            sum.adaptive.final_period_mean.to_bits(),
            direct.final_period.mean().to_bits()
        );
        assert_eq!(sum.adaptive.replicates, 24);
    }

    #[test]
    fn drift_cell_keys_distinguish_schedule_and_knobs_but_seed_ignores_knobs() {
        let s = scenario();
        let mk = |drift, alpha, hyst| {
            let mut g = GridSpec::new(5);
            g.push_drift(s, knee(), 16, drift, alpha, hyst);
            g
        };
        let base = mk(io_ramp(), 0.3, 0.05);
        let other_drift = mk(io_ramp().time_scaled(4.0), 0.3, 0.05);
        let other_alpha = mk(io_ramp(), 0.9, 0.05);
        let other_band = mk(io_ramp(), 0.3, 0.0);
        let key = |g: &GridSpec| g.cell_key(&g.cells()[0]);
        let seed = |g: &GridSpec| g.cell_seed(&g.cells()[0]);
        // The schedule is environment: different cache key AND seed.
        assert_ne!(key(&base), key(&other_drift));
        assert_ne!(seed(&base), seed(&other_drift));
        // The controller knobs are not environment: different cache
        // key, same seed (paired α × band sweeps).
        assert_ne!(key(&base), key(&other_alpha));
        assert_ne!(key(&base), key(&other_band));
        assert_eq!(seed(&base), seed(&other_alpha));
        assert_eq!(seed(&base), seed(&other_band));
        // And a DriftRun never aliases an AdaptiveRun cell.
        let mut adaptive = GridSpec::new(5);
        adaptive.push_adaptive(s, knee(), 16);
        assert_ne!(key(&base), adaptive.cell_key(&adaptive.cells()[0]));
    }

    #[test]
    fn drift_cells_report_lag_and_bounded_regret() {
        let s = scenario();
        let mut spec = GridSpec::new(7);
        spec.push_drift(s, knee(), 24, io_ramp(), 0.3, 0.05);
        let out = spec.evaluate();
        let sum = out[0].output.drift().expect("in domain");
        assert!(
            sum.adaptive.tracking_lag_pct_mean > 0.5,
            "lag {} suspiciously small under a 2x C ramp",
            sum.adaptive.tracking_lag_pct_mean
        );
        assert!(sum.waste_regret_pct.abs() < 3.0, "waste regret {}", sum.waste_regret_pct);
        // Energy regret on the io-heavy ramp is genuinely large: the
        // estimator's period wobble keeps paying the doubled I/O draw
        // (the mirror puts it ~+20pp of the energy floor).
        assert!(
            sum.energy_regret_pct > -10.0 && sum.energy_regret_pct < 45.0,
            "energy regret {}",
            sum.energy_regret_pct
        );
        assert!(
            sum.adaptive.drift_lag_pct_mean > 0.1,
            "drift lag {} suspiciously small under a 2x C ramp",
            sum.adaptive.drift_lag_pct_mean
        );
        assert!(sum.oracle_makespan_mean > s.t_base);
        // Memoised like everything else.
        let again = spec.evaluate();
        assert_eq!(out, again);
    }

    #[test]
    fn drift_out_of_domain_schedule_is_none() {
        // μ decaying to 4%: the trajectory's worst corner leaves the
        // domain, so the cell clamps instead of panicking.
        let s = scenario();
        let bad = crate::drift::DriftProcess::Step {
            at: 100.0,
            to: crate::drift::DriftTargets { c: 1.0, r: 1.0, mu: 0.04, p_io: 1.0 },
        };
        let mut spec = GridSpec::new(1);
        spec.push_drift(s, PeriodPolicy::AlgoT, 8, bad, 0.3, 0.05);
        let out = spec.without_cache().evaluate();
        assert!(matches!(out[0].output, CellOutput::Drift(None)));
        assert_eq!(out[0].output.drift(), None);
    }

    #[test]
    fn adaptive_out_of_domain_is_none() {
        // mu barely above the overheads: no feasible period at all.
        let ckpt = crate::model::CheckpointParams::new(10.0, 10.0, 1.0, 0.5).unwrap();
        let power = crate::model::PowerParams::from_rho(5.5, 1.0, 0.0).unwrap();
        let s = Scenario::new(ckpt, power, 17.0, 1000.0).unwrap();
        let mut spec = GridSpec::new(1);
        spec.push_adaptive(s, PeriodPolicy::AlgoT, 8);
        let out = spec.without_cache().evaluate();
        assert!(matches!(out[0].output, CellOutput::Adaptive(None)));
        assert_eq!(out[0].output.adaptive(), None);
    }

    #[test]
    fn adaptive_cells_memoise_and_stay_bit_stable() {
        let s = fig1_scenario(120.0, 5.5);
        let mut spec = GridSpec::new(0xADA7);
        spec.push_adaptive(s, PeriodPolicy::AlgoE, 24);
        let first = spec.evaluate();
        let (h_before, _) = cache::stats();
        let second = spec.evaluate();
        let (h_after, _) = cache::stats();
        assert!(h_after - h_before >= 1, "expected an adaptive cache hit");
        assert_eq!(first, second);
    }
}
