//! # ckpt-period
//!
//! A production-quality reproduction of **Aupy, Benoit, Hérault, Robert,
//! Dongarra — "Optimal Checkpointing Period: Time vs. Energy" (2013)**.
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the
//!   analytical time/energy model ([`model`]), a discrete-event platform
//!   simulator ([`sim`]), and a fault-tolerant leader/worker training
//!   runtime ([`coordinator`]) that checkpoints a real PJRT-executed
//!   workload with the paper's period policies.
//! * **Layer 2 (python/compile/model.py)** — a JAX transformer training
//!   step, AOT-lowered to HLO text, loaded by [`runtime`].
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (tiled matmul
//!   and a period-sweep evaluator) called from Layer 2.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! program once, and the rust binary is self-contained afterwards.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod figures;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
