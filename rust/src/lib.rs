//! # ckpt-period
//!
//! A production-quality reproduction of **Aupy, Benoit, Hérault, Robert,
//! Dongarra — "Optimal Checkpointing Period: Time vs. Energy" (2013)**.
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the
//!   analytical time/energy model ([`model`]), a discrete-event platform
//!   simulator ([`sim`]), and a fault-tolerant leader/worker training
//!   runtime ([`coordinator`]) that checkpoints a real PJRT-executed
//!   workload with the paper's period policies.
//! * **Layer 2 (python/compile/model.py)** — a JAX transformer training
//!   step, AOT-lowered to HLO text, loaded by [`runtime`].
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (tiled matmul
//!   and a period-sweep evaluator) called from Layer 2.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! program once, and the rust binary is self-contained afterwards. The
//! PJRT execution path is feature-gated (`pjrt`); the default build uses
//! a std-only stub and everything except artifact execution works.
//!
//! # The grid engine
//!
//! All scenario exploration — the paper figures, the ablations, and the
//! CLI `sweep`/`simulate`/`figures` subcommands — routes through one
//! declarative engine, [`sweep::GridSpec`]: a flat batch of
//! (scenario × period × failure-process) cells evaluated on a persistent
//! work-stealing thread pool ([`util::pool::ThreadPool`]). Simulated
//! cells derive their seeds by hashing the spec's base seed with the
//! cell's parameter bits, so grid results are **byte-identical for every
//! thread count** and stable under re-ordering; outputs are memoised
//! process-wide keyed by exact parameter bit patterns
//! ([`sweep::cache`]), so repeated invocations skip recomputation.
//!
//! # The Pareto frontier subsystem
//!
//! [`pareto`] characterises the *range* of time/energy trade-offs the
//! paper's §5 discusses: the exact frontier between `T_Time_opt` and
//! `T_Energy_opt` (dense sampling fanned out on the thread pool and
//! scattered back by index — bit-identical at every thread count —
//! dominance filtering, normalised hypervolume), knee-point detection (max distance to chord, max
//! curvature), ε-constraint solves ("minimise energy subject to a time
//! overhead ≤ x%", and the transpose), and a Monte-Carlo-validated
//! frontier cross-checked against the analytic one through seeded
//! grid-engine sim cells. Frontiers are themselves grid cells
//! ([`sweep::CellJob::Frontier`]), so multi-scenario frontier families
//! are parallel, deterministic, and memo-cached like every other grid;
//! `figures::frontier` renders them and the CLI `pareto` subcommand
//! exports them as JSON artifacts. The whole stack is generic over the
//! objective-model backend ([`model::Backend`]): the paper's
//! first-order closed forms by default, or the exact renewal model
//! (`--model exact`) whose knee sits 6–44% above the first-order one in
//! the frequent-failure regime (`figures::knee_drift`).
//!
//! # Non-stationary environments
//!
//! [`drift`] lifts the whole stack from "one static scenario" to
//! time-varying environments: a [`drift::DriftProcess`] (step / ramp /
//! periodic contention / piecewise schedules over any subset of the
//! scenario's `C`, `R`, `μ`, `P_IO`) bound to a base scenario yields an
//! [`drift::EnvTrajectory`] of deterministic scenario-at-time views.
//! The failure sampler thins non-homogeneous exponential arrivals
//! against the trajectory's rate envelope, `sim::adaptive` drives drift
//! sample paths and records how well the online controller tracks the
//! moving knee (tracking lag, clairvoyant-oracle regret),
//! [`sweep::CellJob::DriftRun`] cells run drift grids parallel and
//! memo-cached, and `figures::drift` sweeps EWMA α × hysteresis band ×
//! drift speed per drift family into `drift.csv`. With a stationary
//! process every consumer is bit-identical to the static path.
//!
//! # Policy-as-a-service
//!
//! [`serve`] turns the solver into a long-lived query service. Clients
//! stream JSON-lines queries — one object per line naming a scenario
//! (trade-off preset or inline [`config::ScenarioSpec`] params), a
//! policy, a model backend, and optionally a drift schedule plus a
//! trajectory time `at` — into `ckpt-period batch` (stdin, a file, or
//! a Unix socket); answers come back one JSON line each, in input
//! order, carrying the chosen period, both objective columns, the
//! backend's per-objective optima and the knee's overhead/gain
//! metadata. Malformed lines become structured `{"line", "error"}`
//! records on stderr without killing the stream or shifting line
//! numbers; batches deduplicate by exact solve-key bits, fan out on
//! the grid engine's thread pool, and serve repeats from a
//! process-wide answer cache, so batch answers are **bit-identical to
//! sequential policy calls at every thread count**. Batches can also
//! be written as a fixed-offset binary artifact ([`serve::wire`]) for
//! zero-copy consumers. `ckpt-period bench` runs the standardised
//! serving workload (cold/warm memo latency, queries/sec at 1/4/8
//! threads, grid cell throughput) and emits the repo-root
//! `BENCH_<n>.json` perf trajectory; see the [`serve`] module docs for
//! the full protocol (grammar, error records, backpressure).
//!
//! # Tiered checkpoint storage
//!
//! [`storage`] replaces the paper's single `(C, R, P_IO)` triple with a
//! multi-level hierarchy — node-local SSD → burst buffer → parallel
//! file system, each level a [`storage::TierSpec`] with its own write
//! cost, restart cost, I/O power draw and copy-retention bound.
//! Checkpoints write synchronously to tier 0 and *drain
//! asynchronously* to slower tiers every κ-th checkpoint; a node loss
//! destroys the local copies, so recovery restarts from the freshest
//! copy on the nearest surviving tier. [`model::tiers`] prices the
//! hierarchy analytically (κ-minimised time/energy envelopes, a
//! numerically-solved optimal period plus per-tier drain-cadence
//! vector, memoised like the exact optima), the DES simulates drain
//! queues and nearest-tier restarts, and the frontier/policy/serve
//! layers accept tiered scenarios end-to-end (`--tiers`, the
//! `ScenarioSpec` `"tiers"` key, `figures::tiers` → `tiers.csv`).
//! Degenerate 1-level hierarchies canonicalise to the scalar model at
//! construction ([`storage::TierConfig::from_tiers`]) and encode to
//! zero extra key words, so every pre-refactor period, frontier point,
//! sample path and solve key is reproduced bit-for-bit.
//!
//! # Observability
//!
//! [`telemetry`] is the one instrumentation surface for the whole
//! stack: a process-wide registry of named counters, gauges and
//! log2-bucket histograms (relaxed atomics, lock-free on the hot
//! path), RAII span timers over the serve engine's
//! parse/dedup/solve/scatter stages, per-job pool latency, grid-cell
//! evaluation and frontier solves, and an opt-in JSONL decision-trace
//! sink for the adaptive controller (`simulate --adaptive --trace`).
//! Rendered as a Prometheus text exposition (a `GET /metrics` request
//! line on the `batch --socket` path, or `info --metrics`) and
//! embedded as percentile snapshots in `bench` v3 artifacts.
//!
//! Every process-wide cache (grid cells, the optimiser memos, tier
//! plans, serve answers) is backed by one sharded store
//! ([`util::shard::ShardedMap`]): 64 shards picked by a fixed-key
//! hash of the exact key bits, each behind its own lock with its own
//! hit/miss counters, so hot warm paths at 8 threads no longer queue
//! on a single mutex and the per-cache aggregates are exact sums of
//! the shard counters. The exposition adds per-shard occupancy rows
//! (`ckpt_cache_shard_entries{cache=...,shard=...}`, occupied shards
//! only), a contended-acquisition histogram
//! (`ckpt_shard_lock_wait_ns` — near-empty is healthy), and the tier
//! envelope pruning counters
//! (`ckpt_tier_envelope_{evaluated,skipped}_total`) whose sum is the
//! full feasible cadence envelope the bound-pruned scans partition.
//!
//! Naming conventions: families are prefixed `ckpt_`, counters end in
//! `_total`, duration histograms in `_ns`; multi-instance concepts
//! (caches, serve stages, pool workers) are one labelled family each.
//! **Adding a metric must not break determinism**: telemetry is
//! observational only — record into it freely, but never read a
//! telemetry value back into a cache key, memo key, seed derivation
//! or any computed result. `tests/telemetry.rs` enforces the contract
//! by pinning instrumented runs bit-identical across thread counts
//! with tracing on and off.
//!
//! # Performance notes
//!
//! Two hot paths have dedicated fast executors, both governed by the
//! same invariant — **execution shape never touches a result bit**:
//!
//! * **Monte Carlo** runs through the batched lockstep executor
//!   ([`sim::batch`]): B replicas per pool job advanced in lockstep
//!   over struct-of-arrays state, with block-drawn failure samples per
//!   replica stream and allocation-free event steps. Replicas are
//!   independent (replica `i` owns `seed + i`), so lockstep
//!   interleaving preserves every replica's own operation sequence and
//!   the batched results are bit-for-bit the per-replica loop's — the
//!   retained `#[doc(hidden)]` reference drivers and
//!   `tests/batch_sim.rs` pin exactly that. The batch size (`--batch`,
//!   auto ≈ 4 jobs per pool participant, capped so a block stays
//!   cache-resident) is an execution-shape knob like the thread count.
//!   `BENCH_3.json`: 3.4–3.9× the scalar fan-out's replicas/sec at
//!   1–8 threads.
//! * **Exact-backend re-solves warm-start** from a per-family hint
//!   store ([`model::backend`]): scenarios sharing every parameter a
//!   drift schedule cannot rescale form one family, and successive
//!   solves seed a 3-probe bracket around the family's previous
//!   optimum ([`model::optimize::grid_then_golden_warm`]) instead of
//!   rescanning ~400 grid points. The bracket only validates when it
//!   reproduces the cold scan's geometry exactly, and fails open to
//!   the cold path bit-identically — hints can make solves faster,
//!   never different. Observability: `ckpt_opt_warm_{hits,fallbacks}_total`.
//!
//! The serving bench (`ckpt-period bench`, schema v4) measures both
//! legs on every PR and `bench --gate` fails CI on >15% regressions.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod drift;
pub mod energy;
pub mod figures;
pub mod model;
pub mod pareto;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod sweep;
pub mod telemetry;
pub mod util;
pub mod workload;
