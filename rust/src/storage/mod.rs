//! Tiered checkpoint storage: the multi-level `(C_i, R_i, P_IO_i)`
//! hierarchy behind the scalar model.
//!
//! The paper prices every checkpoint with one `(C, R, P_IO)` triple —
//! one storage device. Real Exascale stacks (VELOC-style) write
//! **synchronously to node-local storage** (tier 0: cheap, but a node
//! loss takes the copy with it) and **drain asynchronously** to slower,
//! safer tiers (burst buffer, then the parallel file system), restarting
//! from the nearest tier that still holds a usable copy.
//!
//! This module owns the data model for that hierarchy:
//!
//! * [`TierSpec`] — one level's write cost `c`, read/restart cost `r`,
//!   I/O power draw `p_io`, and copy bounds (`capacity`, `retention`).
//! * [`TierHierarchy`] — an ordered, validated stack of 1..=[`MAX_TIERS`]
//!   levels, fastest (node-local) first. Fixed-size and `Copy` so a
//!   [`crate::model::Scenario`] can embed it without losing `Copy`.
//! * [`TierConfig`] — `Scalar` (the paper's model, byte-for-byte) or
//!   `Tiered`. Every pre-existing constructor produces `Scalar`, and a
//!   1-level hierarchy *canonicalises* to `Scalar`, so degenerate
//!   hierarchies reproduce the scalar model bit-for-bit by construction.
//! * [`TierStore`] — the discrete-event simulator's view: which copies
//!   exist on which tier, when each became usable (drain completion),
//!   newest-K eviction per tier, and nearest-surviving-tier lookup
//!   under node-loss scope (tier 0 dies with the node; tiers ≥ 1
//!   survive).
//!
//! Failure-scope semantics: a failure is a *node* loss. Copies on
//! tier 0 (node-local SSD) are destroyed; copies on tiers ≥ 1 (burst
//! buffer, PFS) survive. Recovery reads the freshest surviving copy
//! whose drain completed before the failure; ties prefer the fastest
//! (lowest) tier. The analytical counterpart lives in
//! [`crate::model::tiers`].
//!
//! Key material: [`TierConfig::key_words`] is the exact-bits extension
//! appended to [`crate::model::Scenario::key_words`]. `Scalar` encodes
//! to **zero words**, which is what keeps every pre-existing memo key,
//! cache key and derived seed bit-identical.

/// Maximum number of storage levels (node-local SSD, burst buffer, PFS,
/// plus one spare). Fixed so the hierarchy stays `Copy`.
pub const MAX_TIERS: usize = 4;

/// One storage level. Times in minutes, power in the same per-node
/// units as [`crate::model::PowerParams`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    /// Write cost `C_i`: wall-clock minutes to land one checkpoint on
    /// this tier (synchronous for tier 0, drain duration for tiers ≥ 1).
    pub c: f64,
    /// Read cost `R_i`: wall-clock minutes to restart from this tier.
    pub r: f64,
    /// I/O power draw `P_IO_i` while reading/writing this tier.
    pub p_io: f64,
    /// Maximum simultaneous copies held on this tier (0 = unbounded).
    pub capacity: u32,
    /// Keep only the newest `retention` checkpoints (0 = unbounded).
    pub retention: u32,
}

impl TierSpec {
    /// Unbounded tier (no capacity/retention limits).
    pub fn new(c: f64, r: f64, p_io: f64) -> Self {
        TierSpec { c, r, p_io, capacity: 0, retention: 0 }
    }

    pub fn with_limits(c: f64, r: f64, p_io: f64, capacity: u32, retention: u32) -> Self {
        TierSpec { c, r, p_io, capacity, retention }
    }

    fn validate(&self, idx: usize) -> Result<(), String> {
        if !(self.c > 0.0 && self.c.is_finite()) {
            return Err(format!("tier {idx}: c must be > 0, got {}", self.c));
        }
        if !(self.r >= 0.0 && self.r.is_finite()) {
            return Err(format!("tier {idx}: r must be >= 0, got {}", self.r));
        }
        if !(self.p_io >= 0.0 && self.p_io.is_finite()) {
            return Err(format!("tier {idx}: io must be >= 0, got {}", self.p_io));
        }
        Ok(())
    }

    /// Effective copy bound: the tightest of the non-zero limits
    /// (`None` = unbounded).
    pub fn keep_bound(&self) -> Option<usize> {
        match (self.capacity, self.retention) {
            (0, 0) => None,
            (c, 0) => Some(c as usize),
            (0, k) => Some(k as usize),
            (c, k) => Some(c.min(k) as usize),
        }
    }
}

/// An ordered stack of 1..=[`MAX_TIERS`] storage levels, fastest first.
/// Embedded in a scenario it always has ≥ 2 levels: 1-level stacks
/// canonicalise to [`TierConfig::Scalar`] at the [`TierConfig::from_tiers`]
/// entry point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierHierarchy {
    specs: [TierSpec; MAX_TIERS],
    n: u8,
}

impl TierHierarchy {
    /// Validated hierarchy from a slice of 1..=[`MAX_TIERS`] specs.
    /// (A 1-level hierarchy is legal here; [`TierConfig::from_tiers`]
    /// is the canonicalising entry point.)
    pub fn new(tiers: &[TierSpec]) -> Result<Self, String> {
        if tiers.is_empty() {
            return Err("hierarchy needs at least 1 tier".into());
        }
        if tiers.len() > MAX_TIERS {
            return Err(format!("at most {MAX_TIERS} tiers supported, got {}", tiers.len()));
        }
        for (i, t) in tiers.iter().enumerate() {
            t.validate(i)?;
        }
        let mut specs = [TierSpec::new(1.0, 0.0, 0.0); MAX_TIERS];
        specs[..tiers.len()].copy_from_slice(tiers);
        Ok(TierHierarchy { specs, n: tiers.len() as u8 })
    }

    /// Number of levels (1..=[`MAX_TIERS`]).
    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        false // by construction: `new` rejects empty hierarchies
    }

    /// Level `i` (0 = fastest / node-local). Panics if out of range.
    pub fn tier(&self, i: usize) -> &TierSpec {
        assert!(i < self.len(), "tier index {i} out of range (n={})", self.n);
        &self.specs[i]
    }

    pub fn iter(&self) -> impl Iterator<Item = &TierSpec> {
        self.specs[..self.len()].iter()
    }

    /// Exact-bits key words for this hierarchy: the level count, then
    /// every field of every level. The exhaustive destructuring makes
    /// adding a `TierSpec` field a compile error here, mirroring the
    /// `Scenario::key_bits` convention.
    pub fn key_words(&self) -> Vec<u64> {
        let mut k = Vec::with_capacity(1 + 5 * self.len());
        k.push(self.n as u64);
        for spec in self.iter() {
            let TierSpec { c, r, p_io, capacity, retention } = *spec;
            k.push(c.to_bits());
            k.push(r.to_bits());
            k.push(p_io.to_bits());
            k.push(capacity as u64);
            k.push(retention as u64);
        }
        k
    }
}

/// A scenario's storage model: the paper's scalar triple, or a
/// multi-level hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum TierConfig {
    /// The pre-refactor scalar model: one `(C, R, P_IO)` triple read
    /// from `Scenario { ckpt, power, .. }`. Encodes to zero key words.
    #[default]
    Scalar,
    /// A ≥2-level hierarchy. The scenario's scalar fields hold the
    /// *effective projection* (tier-0 write cost, tier-1 restart cost,
    /// tier-0 I/O power); the hierarchy carries the full structure.
    Tiered(TierHierarchy),
}

impl TierConfig {
    /// Canonicalising constructor: a 1-level hierarchy **is** the scalar
    /// model, so it becomes [`TierConfig::Scalar`] — the bit-for-bit
    /// degenerate-equivalence guarantee falls out of this.
    pub fn from_tiers(tiers: &[TierSpec]) -> Result<Self, String> {
        let h = TierHierarchy::new(tiers)?;
        if h.len() == 1 {
            Ok(TierConfig::Scalar)
        } else {
            Ok(TierConfig::Tiered(h))
        }
    }

    /// The hierarchy, when there is more than one level.
    pub fn hierarchy(&self) -> Option<&TierHierarchy> {
        match self {
            TierConfig::Scalar => None,
            TierConfig::Tiered(h) => Some(h),
        }
    }

    pub fn is_scalar(&self) -> bool {
        matches!(self, TierConfig::Scalar)
    }

    /// Exact-bits key extension. **Empty for `Scalar`** — every
    /// pre-existing key/seed derivation stays bit-identical.
    pub fn key_words(&self) -> Vec<u64> {
        match self {
            TierConfig::Scalar => Vec::new(),
            TierConfig::Tiered(h) => h.key_words(),
        }
    }
}

/// Grammar for `--tiers` and the serve wire: tiers separated by `/`,
/// fastest first, each `c=<f>,r=<f>,io=<f>[,cap=<n>][,keep=<n>]`.
///
/// Example: `c=1,r=1,io=30/c=10,r=10,io=100,keep=2`.
pub const TIER_GRAMMAR: &str = "c=<min>,r=<min>,io=<power>[,cap=<n>][,keep=<n>] \
                                joined by '/' fastest-first (1-4 tiers), e.g. \
                                c=1,r=1,io=30/c=10,r=10,io=100";

/// Parse the [`TIER_GRAMMAR`] into a (canonicalised) [`TierConfig`].
pub fn parse_tiers(input: &str) -> Result<TierConfig, String> {
    TierConfig::from_tiers(&parse_tier_specs(input)?)
}

/// Parse the [`TIER_GRAMMAR`] into raw specs, fastest first — for
/// callers (the `--tiers` flag) that need a 1-level spec's fields
/// *before* [`TierConfig::from_tiers`] canonicalises it away. Count
/// and field validation happen at hierarchy construction.
pub fn parse_tier_specs(input: &str) -> Result<Vec<TierSpec>, String> {
    let mut tiers = Vec::new();
    for (idx, part) in input.split('/').enumerate() {
        let mut c = None;
        let mut r = None;
        let mut io = None;
        let mut cap = 0u32;
        let mut keep = 0u32;
        for field in part.split(',') {
            let field = field.trim();
            let (key, val) = field
                .split_once('=')
                .ok_or_else(|| format!("tier {idx}: expected key=value, got '{field}'"))?;
            match key.trim() {
                "c" => c = Some(parse_f64(idx, "c", val)?),
                "r" => r = Some(parse_f64(idx, "r", val)?),
                "io" => io = Some(parse_f64(idx, "io", val)?),
                "cap" => cap = parse_u32(idx, "cap", val)?,
                "keep" => keep = parse_u32(idx, "keep", val)?,
                other => return Err(format!("tier {idx}: unknown field '{other}'")),
            }
        }
        let c = c.ok_or_else(|| format!("tier {idx}: missing required field 'c'"))?;
        let r = r.ok_or_else(|| format!("tier {idx}: missing required field 'r'"))?;
        let io = io.ok_or_else(|| format!("tier {idx}: missing required field 'io'"))?;
        tiers.push(TierSpec::with_limits(c, r, io, cap, keep));
    }
    Ok(tiers)
}

fn parse_f64(idx: usize, key: &str, val: &str) -> Result<f64, String> {
    val.trim()
        .parse::<f64>()
        .map_err(|_| format!("tier {idx}: field '{key}' is not a number: '{val}'"))
}

fn parse_u32(idx: usize, key: &str, val: &str) -> Result<u32, String> {
    val.trim()
        .parse::<u32>()
        .map_err(|_| format!("tier {idx}: field '{key}' is not a count: '{val}'"))
}

/// A checkpoint copy held on some tier during a simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CopyRecord {
    /// Work units captured by this checkpoint (restart resumes here).
    pub work: f64,
    /// Simulation time at which the copy became usable (write or drain
    /// completion).
    pub available_at: f64,
}

/// The DES-side store: per-tier copy lists with newest-K eviction and
/// nearest-surviving-tier recovery lookup.
///
/// Eviction never removes a tier's freshest copy, and never removes a
/// copy pinned as the source of an in-flight drain (the drain would
/// silently lose its data otherwise).
#[derive(Debug, Clone)]
pub struct TierStore {
    /// `copies[i]` sorted by insertion order == ascending `work`.
    copies: Vec<Vec<CopyRecord>>,
    bounds: Vec<Option<usize>>,
}

impl TierStore {
    pub fn new(h: &TierHierarchy) -> Self {
        TierStore {
            copies: vec![Vec::new(); h.len()],
            bounds: h.iter().map(|t| t.keep_bound()).collect(),
        }
    }

    /// Record a landed copy on `tier`, then evict beyond the tier's
    /// bound — oldest first, skipping the freshest copy and any copy
    /// whose `work` appears in `pinned` (in-flight drain sources).
    pub fn record(&mut self, tier: usize, copy: CopyRecord, pinned: &[f64]) {
        let list = &mut self.copies[tier];
        list.push(copy);
        if let Some(bound) = self.bounds[tier] {
            let bound = bound.max(1);
            let mut i = 0;
            while list.len() > bound && i < list.len() - 1 {
                let w = list[i].work;
                if pinned.iter().any(|&p| p.to_bits() == w.to_bits()) {
                    i += 1; // pinned: try the next-oldest instead
                } else {
                    list.remove(i);
                }
            }
        }
    }

    /// Copies currently held on `tier` (test/diagnostic use).
    pub fn tier_copies(&self, tier: usize) -> &[CopyRecord] {
        &self.copies[tier]
    }

    /// A node loss destroys every tier-0 (node-local) copy.
    pub fn purge_node_local(&mut self) {
        if let Some(local) = self.copies.first_mut() {
            local.clear();
        }
    }

    /// Freshest copy usable at a failure striking at `fail_at`:
    /// maximum `work` over all tiers ≥ 1 (tier 0 just died with the
    /// node) with `available_at <= fail_at`; ties prefer the lowest
    /// (fastest) tier. `None` means restart from scratch.
    pub fn freshest_surviving(&self, fail_at: f64) -> Option<(usize, CopyRecord)> {
        let mut best: Option<(usize, CopyRecord)> = None;
        for (tier, list) in self.copies.iter().enumerate().skip(1) {
            for &c in list {
                if c.available_at <= fail_at {
                    let better = match best {
                        None => true,
                        Some((_, b)) => c.work > b.work,
                    };
                    if better {
                        best = Some((tier, c));
                    }
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_level() -> TierHierarchy {
        TierHierarchy::new(&[
            TierSpec::new(1.0, 1.0, 30.0),
            TierSpec::new(10.0, 10.0, 100.0),
        ])
        .unwrap()
    }

    #[test]
    fn single_tier_canonicalises_to_scalar() {
        let cfg = TierConfig::from_tiers(&[TierSpec::new(10.0, 10.0, 100.0)]).unwrap();
        assert!(cfg.is_scalar());
        assert!(cfg.hierarchy().is_none());
        assert!(cfg.key_words().is_empty());
    }

    #[test]
    fn multi_tier_keeps_hierarchy() {
        let cfg = TierConfig::from_tiers(&[
            TierSpec::new(1.0, 1.0, 30.0),
            TierSpec::new(10.0, 10.0, 100.0),
        ])
        .unwrap();
        let h = cfg.hierarchy().unwrap();
        assert_eq!(h.len(), 2);
        assert_eq!(h.tier(0).c, 1.0);
        assert_eq!(h.tier(1).p_io, 100.0);
    }

    #[test]
    fn hierarchy_validation() {
        assert!(TierHierarchy::new(&[]).is_err());
        assert!(TierHierarchy::new(&[TierSpec::new(0.0, 1.0, 1.0)]).is_err());
        assert!(TierHierarchy::new(&[TierSpec::new(1.0, -1.0, 1.0)]).is_err());
        assert!(TierHierarchy::new(&[TierSpec::new(1.0, 1.0, f64::NAN)]).is_err());
        let five = [TierSpec::new(1.0, 1.0, 1.0); 5];
        assert!(TierHierarchy::new(&five).is_err());
    }

    #[test]
    fn key_words_cover_every_field_of_every_tier() {
        let base = two_level();
        let bits = base.key_words();
        assert_eq!(bits.len(), 1 + 5 * 2);
        assert_eq!(bits[0], 2, "leading word is the level count");
        // Each field perturbation changes the key.
        for field in 0..5 {
            for tier in 0..2 {
                let mut specs: Vec<TierSpec> = base.iter().copied().collect();
                match field {
                    0 => specs[tier].c += 1.0,
                    1 => specs[tier].r += 1.0,
                    2 => specs[tier].p_io += 1.0,
                    3 => specs[tier].capacity += 1,
                    _ => specs[tier].retention += 1,
                }
                let v = TierHierarchy::new(&specs).unwrap();
                assert_ne!(v.key_words(), bits, "tier {tier} field {field} not covered");
            }
        }
        // Level count is covered too.
        let mut specs: Vec<TierSpec> = base.iter().copied().collect();
        specs.push(TierSpec::new(20.0, 20.0, 200.0));
        assert_ne!(TierHierarchy::new(&specs).unwrap().key_words(), bits);
    }

    #[test]
    fn grammar_roundtrip_and_errors() {
        let cfg = parse_tiers("c=1,r=1,io=30/c=10,r=10,io=100,keep=2").unwrap();
        let h = cfg.hierarchy().unwrap();
        assert_eq!(h.len(), 2);
        assert_eq!(h.tier(1).retention, 2);
        assert_eq!(h.tier(1).capacity, 0);
        // Single tier canonicalises.
        assert!(parse_tiers("c=10,r=10,io=100").unwrap().is_scalar());
        // Errors.
        assert!(parse_tiers("").is_err());
        assert!(parse_tiers("c=1,r=1").is_err(), "missing io");
        assert!(parse_tiers("c=1,r=1,io=x").is_err(), "non-numeric");
        assert!(parse_tiers("c=1,r=1,io=1,zap=2").is_err(), "unknown field");
        assert!(parse_tiers("c=1,r=1,io=1,cap=1.5").is_err(), "non-integer cap");
        assert!(parse_tiers("c=0,r=1,io=1/c=1,r=1,io=1").is_err(), "c=0 invalid");
    }

    #[test]
    fn store_recovery_prefers_freshest_then_fastest() {
        let h = TierHierarchy::new(&[
            TierSpec::new(1.0, 1.0, 30.0),
            TierSpec::new(2.0, 3.0, 60.0),
            TierSpec::new(10.0, 10.0, 100.0),
        ])
        .unwrap();
        let mut store = TierStore::new(&h);
        store.record(0, CopyRecord { work: 50.0, available_at: 51.0 }, &[]);
        store.record(1, CopyRecord { work: 40.0, available_at: 45.0 }, &[]);
        store.record(2, CopyRecord { work: 40.0, available_at: 60.0 }, &[]);
        // Tier-0 copy is freshest but dies with the node; tier-1 copy of
        // the same work as tier-2 wins on tier order; the tier-2 copy is
        // not yet available at t=50.
        let (tier, copy) = store.freshest_surviving(50.0).unwrap();
        assert_eq!(tier, 1);
        assert_eq!(copy.work, 40.0);
        // After the tier-2 drain lands, work ties still pick tier 1.
        let (tier, _) = store.freshest_surviving(61.0).unwrap();
        assert_eq!(tier, 1);
        // A fresher tier-2 copy beats the older tier-1 copy.
        store.record(2, CopyRecord { work: 48.0, available_at: 62.0 }, &[]);
        let (tier, copy) = store.freshest_surviving(63.0).unwrap();
        assert_eq!(tier, 2);
        assert_eq!(copy.work, 48.0);
        // Nothing survives at t=0.
        assert!(store.freshest_surviving(0.0).is_none());
    }

    #[test]
    fn node_loss_purges_only_tier0() {
        let h = two_level();
        let mut store = TierStore::new(&h);
        store.record(0, CopyRecord { work: 10.0, available_at: 11.0 }, &[]);
        store.record(1, CopyRecord { work: 10.0, available_at: 21.0 }, &[]);
        store.purge_node_local();
        assert!(store.tier_copies(0).is_empty());
        assert_eq!(store.tier_copies(1).len(), 1);
    }

    #[test]
    fn eviction_keeps_newest_k_and_pins() {
        let h = TierHierarchy::new(&[
            TierSpec::new(1.0, 1.0, 30.0),
            TierSpec::with_limits(10.0, 10.0, 100.0, 0, 2),
        ])
        .unwrap();
        let mut store = TierStore::new(&h);
        for i in 0..4 {
            let w = 10.0 * (i + 1) as f64;
            store.record(1, CopyRecord { work: w, available_at: w + 1.0 }, &[]);
        }
        let works: Vec<f64> = store.tier_copies(1).iter().map(|c| c.work).collect();
        assert_eq!(works, vec![30.0, 40.0], "newest 2 retained");
        // A pinned old copy survives; the next-oldest unpinned one goes.
        store.record(1, CopyRecord { work: 50.0, available_at: 51.0 }, &[30.0]);
        let works: Vec<f64> = store.tier_copies(1).iter().map(|c| c.work).collect();
        assert_eq!(works, vec![30.0, 50.0], "pinned 30 kept, 40 evicted");
    }

    #[test]
    fn capacity_and_retention_tightest_wins() {
        assert_eq!(TierSpec::with_limits(1.0, 1.0, 1.0, 3, 2).keep_bound(), Some(2));
        assert_eq!(TierSpec::with_limits(1.0, 1.0, 1.0, 2, 3).keep_bound(), Some(2));
        assert_eq!(TierSpec::with_limits(1.0, 1.0, 1.0, 0, 3).keep_bound(), Some(3));
        assert_eq!(TierSpec::new(1.0, 1.0, 1.0).keep_bound(), None);
    }
}
