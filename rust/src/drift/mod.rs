//! Non-stationary environments: time-varying drift over [`Scenario`]
//! parameters.
//!
//! The paper fixes `(C, R, μ, P_IO)` for the whole execution. The
//! Exascale reality its adaptive descendants target — and the reason
//! runtimes like VELOC re-estimate online — is that these parameters
//! *drift* over a run: parallel-file-system contention ramps checkpoint
//! cost up, component wear-out decays the platform MTBF, malleable
//! reconfiguration steps the checkpoint size. This module is the
//! crate's model of that reality:
//!
//! * [`DriftProcess`] — a deterministic schedule of multiplicative
//!   drift over a subset of the scenario's fields ([`DriftTargets`]):
//!   step change, linear ramp, periodic contention (square wave), or a
//!   two-segment piecewise schedule. [`DriftProcess::Stationary`] is
//!   the identity — the paper's world.
//! * [`EnvTrajectory`] — a scenario bound to a drift process: the
//!   deterministic *scenario-at-time* view every consumer reads.
//!   `scenario_at(t)` returns the base scenario **bit-for-bit** when
//!   the process is (effectively) stationary, which is what the
//!   zero-regression guarantee of the whole drift stack rests on; the
//!   trajectory views are quantisable downstream exactly like static
//!   scenarios (the online-policy memo quantises `(C, R, μ)` to three
//!   significant digits per [`crate::pareto::online`]).
//!
//! Consumers:
//!
//! * [`crate::sim::failure`] samples non-homogeneous exponential
//!   failures by thinning against the trajectory's rate envelope
//!   ([`EnvTrajectory::min_mu`]).
//! * [`crate::sim::adaptive`] drives drift sample paths and records
//!   how well the online controller tracks the moving policy period
//!   (tracking lag, oracle regret).
//! * [`crate::sweep`] runs drift grids as
//!   [`CellJob::DriftRun`](crate::sweep::CellJob::DriftRun) cells —
//!   parallel, memo-cached, drift encoded in the cache key.
//! * [`crate::figures::drift`] sweeps EWMA α × hysteresis band × drift
//!   speed per drift family into `drift.csv`.
//! * The CLI accepts the [`DriftProcess::parse`] grammar via
//!   `--drift` on `simulate --adaptive` and `train`.
//!
//! Drift is *deterministic* (a schedule, not a stochastic process):
//! sample-path randomness stays where it always was — in the failure
//! draws — so drift runs inherit the crate's seeding contract
//! unchanged and stay byte-identical across thread counts.

use crate::model::params::{ModelError, Scenario};

/// Multiplicative drift targets: one multiplier per driftable scenario
/// field. `1.0` leaves a field untouched, so "any subset of fields" is
/// expressed by setting the rest to the identity. Only the fields an
/// environment can physically drift are exposed: the checkpoint write
/// cost `C`, the recovery read cost `R`, the platform MTBF `μ`, and the
/// I/O power draw `P_IO` (a saturated file system is busy longer *and*
/// draws more). `D`, `ω`, the CPU powers and `T_base` are configuration,
/// not environment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftTargets {
    /// Multiplier on the checkpoint duration `C`.
    pub c: f64,
    /// Multiplier on the recovery duration `R`.
    pub r: f64,
    /// Multiplier on the platform MTBF `μ` (`< 1` = wear-out).
    pub mu: f64,
    /// Multiplier on the I/O power draw `P_IO`.
    pub p_io: f64,
}

impl DriftTargets {
    /// The identity: no field drifts.
    pub const ONE: DriftTargets = DriftTargets { c: 1.0, r: 1.0, mu: 1.0, p_io: 1.0 };

    pub fn is_identity(&self) -> bool {
        *self == Self::ONE
    }

    /// Multipliers must be finite and strictly positive (a zero `C` or
    /// `μ` multiplier is not a drift, it is a different model).
    pub fn validate(&self) -> Result<(), ModelError> {
        for (name, v) in [("c", self.c), ("r", self.r), ("mu", self.mu), ("io", self.p_io)] {
            if !(v.is_finite() && v > 0.0) {
                return Err(ModelError::Invalid(format!(
                    "drift multiplier `{name}` must be finite and > 0, got {v}"
                )));
            }
        }
        Ok(())
    }

    /// Componentwise linear interpolation from the identity toward
    /// `self`: `w = 0` is the identity, `w = 1` is `self`.
    fn lerp_from_one(&self, w: f64) -> DriftTargets {
        let lerp = |to: f64| 1.0 + (to - 1.0) * w;
        DriftTargets { c: lerp(self.c), r: lerp(self.r), mu: lerp(self.mu), p_io: lerp(self.p_io) }
    }

    /// Componentwise envelope of two target sets in the direction that
    /// *shrinks* the model's domain: larger `C`/`R`, smaller `μ`. Used
    /// to validate the worst corner a schedule can reach.
    fn domain_worst(a: DriftTargets, b: DriftTargets) -> DriftTargets {
        DriftTargets {
            c: a.c.max(b.c),
            r: a.r.max(b.r),
            mu: a.mu.min(b.mu),
            p_io: a.p_io.max(b.p_io),
        }
    }

    fn key_bits(&self) -> [u64; 4] {
        [self.c.to_bits(), self.r.to_bits(), self.mu.to_bits(), self.p_io.to_bits()]
    }

    /// Parse a `c=2,r=2,mu=0.5,io=2` field list (each field at most
    /// once, at least one field, every multiplier finite and > 0).
    fn parse(s: &str) -> Option<DriftTargets> {
        let mut t = DriftTargets::ONE;
        let mut seen = [false; 4];
        for item in s.split(',') {
            let (field, value) = item.split_once('=')?;
            let v = value.parse::<f64>().ok()?;
            let slot = match field {
                "c" => {
                    t.c = v;
                    0
                }
                "r" => {
                    t.r = v;
                    1
                }
                "mu" => {
                    t.mu = v;
                    2
                }
                "io" => {
                    t.p_io = v;
                    3
                }
                _ => return None,
            };
            if seen[slot] {
                return None;
            }
            seen[slot] = true;
        }
        if !seen.iter().any(|&s| s) {
            return None;
        }
        t.validate().ok()?;
        Some(t)
    }

    fn render(&self) -> String {
        let mut parts = Vec::new();
        if self.c != 1.0 {
            parts.push(format!("c={}", self.c));
        }
        if self.r != 1.0 {
            parts.push(format!("r={}", self.r));
        }
        if self.mu != 1.0 {
            parts.push(format!("mu={}", self.mu));
        }
        if self.p_io != 1.0 {
            parts.push(format!("io={}", self.p_io));
        }
        if parts.is_empty() {
            "c=1".into()
        } else {
            parts.join(",")
        }
    }
}

/// A deterministic drift schedule: the multiplier set in force at each
/// absolute run time `t ≥ 0` (minutes, the scenario's units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftProcess {
    /// No drift — the paper's stationary world. The identity of the
    /// whole layer: every consumer must behave **bit-identically** to
    /// the pre-drift code under `Stationary`.
    Stationary,
    /// Multipliers jump from the identity to `to` at time `at`
    /// (inclusive) and stay there — malleable reconfiguration.
    Step { at: f64, to: DriftTargets },
    /// Multipliers ramp linearly from the identity at `from_t` to `to`
    /// at `to_t` and hold afterwards — I/O contention building up,
    /// gradual wear-out.
    Ramp { from_t: f64, to_t: f64, to: DriftTargets },
    /// Square-wave contention: multipliers are `to` during the first
    /// `duty` fraction of every window of length `period`, identity for
    /// the rest — periodic bursts from co-scheduled jobs.
    Contention { period: f64, duty: f64, to: DriftTargets },
    /// Two-segment piecewise-constant schedule: identity before `t1`,
    /// `first` on `[t1, t2)`, `second` from `t2` on.
    Piecewise { t1: f64, first: DriftTargets, t2: f64, second: DriftTargets },
}

impl DriftProcess {
    /// The accepted `--drift` spellings, for CLI help and error
    /// messages (named presets from
    /// [`crate::config::presets::drift_presets`] are accepted on top).
    pub const PARSE_HELP: &'static str = "stationary|step:<at>:<f=m,..>|ramp:<t0>:<t1>:<f=m,..>|\
         contention:<period>:<duty>:<f=m,..>|piecewise:<t1>:<f=m,..>:<t2>:<f=m,..> \
         with fields c|r|mu|io and finite multipliers > 0";

    /// Stable display name of the schedule shape.
    pub fn name(&self) -> &'static str {
        match self {
            DriftProcess::Stationary => "stationary",
            DriftProcess::Step { .. } => "step",
            DriftProcess::Ramp { .. } => "ramp",
            DriftProcess::Contention { .. } => "contention",
            DriftProcess::Piecewise { .. } => "piecewise",
        }
    }

    /// Validate the schedule's shape parameters and targets.
    pub fn validate(&self) -> Result<(), ModelError> {
        let time_ok = |name: &str, t: f64| {
            if t.is_finite() && t >= 0.0 {
                Ok(())
            } else {
                Err(ModelError::Invalid(format!(
                    "drift time `{name}` must be finite and >= 0, got {t}"
                )))
            }
        };
        match self {
            DriftProcess::Stationary => Ok(()),
            DriftProcess::Step { at, to } => {
                time_ok("at", *at)?;
                to.validate()
            }
            DriftProcess::Ramp { from_t, to_t, to } => {
                time_ok("from_t", *from_t)?;
                time_ok("to_t", *to_t)?;
                if to_t <= from_t {
                    return Err(ModelError::Invalid(format!(
                        "ramp needs to_t > from_t, got [{from_t}, {to_t}]"
                    )));
                }
                to.validate()
            }
            DriftProcess::Contention { period, duty, to } => {
                if !(period.is_finite() && *period > 0.0) {
                    return Err(ModelError::Invalid(format!(
                        "contention period must be finite and > 0, got {period}"
                    )));
                }
                if !(duty.is_finite() && (0.0..=1.0).contains(duty)) {
                    return Err(ModelError::Invalid(format!(
                        "contention duty must be in [0, 1], got {duty}"
                    )));
                }
                to.validate()
            }
            DriftProcess::Piecewise { t1, first, t2, second } => {
                time_ok("t1", *t1)?;
                time_ok("t2", *t2)?;
                if t2 < t1 {
                    return Err(ModelError::Invalid(format!(
                        "piecewise needs t2 >= t1, got t1={t1} t2={t2}"
                    )));
                }
                first.validate()?;
                second.validate()
            }
        }
    }

    /// The multiplier set in force at time `t`.
    pub fn targets_at(&self, t: f64) -> DriftTargets {
        match self {
            DriftProcess::Stationary => DriftTargets::ONE,
            DriftProcess::Step { at, to } => {
                if t >= *at {
                    *to
                } else {
                    DriftTargets::ONE
                }
            }
            DriftProcess::Ramp { from_t, to_t, to } => {
                if t <= *from_t {
                    DriftTargets::ONE
                } else if t >= *to_t {
                    *to
                } else {
                    to.lerp_from_one((t - from_t) / (to_t - from_t))
                }
            }
            DriftProcess::Contention { period, duty, to } => {
                if t.rem_euclid(*period) < duty * period {
                    *to
                } else {
                    DriftTargets::ONE
                }
            }
            DriftProcess::Piecewise { t1, first, t2, second } => {
                if t >= *t2 {
                    *second
                } else if t >= *t1 {
                    *first
                } else {
                    DriftTargets::ONE
                }
            }
        }
    }

    /// Whether the schedule is the identity for all `t` — either
    /// `Stationary` itself, or a shape whose reachable targets are all
    /// the identity. Consumers use this to route onto the exact
    /// pre-drift code paths (bit-identical output).
    pub fn is_stationary(&self) -> bool {
        match self {
            DriftProcess::Stationary => true,
            DriftProcess::Step { to, .. } | DriftProcess::Ramp { to, .. } => to.is_identity(),
            DriftProcess::Contention { duty, to, .. } => to.is_identity() || *duty == 0.0,
            DriftProcess::Piecewise { first, second, .. } => {
                first.is_identity() && second.is_identity()
            }
        }
    }

    /// The componentwise worst multipliers the schedule can reach, in
    /// the direction that shrinks the model's domain (max `C`/`R`
    /// stretch, min `μ`). Every reachable target set lies componentwise
    /// between the identity and this envelope, so validating the
    /// scenario at this corner validates the whole trajectory.
    pub fn domain_worst_targets(&self) -> DriftTargets {
        match self {
            DriftProcess::Stationary => DriftTargets::ONE,
            DriftProcess::Step { to, .. }
            | DriftProcess::Ramp { to, .. }
            | DriftProcess::Contention { to, .. } => {
                DriftTargets::domain_worst(DriftTargets::ONE, *to)
            }
            DriftProcess::Piecewise { first, second, .. } => DriftTargets::domain_worst(
                DriftTargets::ONE,
                DriftTargets::domain_worst(*first, *second),
            ),
        }
    }

    /// The same schedule restricted to its `μ` component (identity on
    /// every other field). The wall-clock coordinator uses this: it
    /// can drive the failure injector's rate, but `C`/`R` are real
    /// measured durations it cannot script.
    pub fn mu_only(&self) -> DriftProcess {
        let strip = |t: DriftTargets| DriftTargets { mu: t.mu, ..DriftTargets::ONE };
        match *self {
            DriftProcess::Stationary => DriftProcess::Stationary,
            DriftProcess::Step { at, to } => DriftProcess::Step { at, to: strip(to) },
            DriftProcess::Ramp { from_t, to_t, to } => {
                DriftProcess::Ramp { from_t, to_t, to: strip(to) }
            }
            DriftProcess::Contention { period, duty, to } => {
                DriftProcess::Contention { period, duty, to: strip(to) }
            }
            DriftProcess::Piecewise { t1, first, t2, second } => DriftProcess::Piecewise {
                t1,
                first: strip(first),
                t2,
                second: strip(second),
            },
        }
    }

    /// The same schedule with its time axis compressed by `speed` (> 1
    /// = the environment drifts faster). The figure's "drift speed"
    /// axis.
    pub fn time_scaled(&self, speed: f64) -> DriftProcess {
        assert!(speed.is_finite() && speed > 0.0, "speed must be finite and > 0, got {speed}");
        match *self {
            DriftProcess::Stationary => DriftProcess::Stationary,
            DriftProcess::Step { at, to } => DriftProcess::Step { at: at / speed, to },
            DriftProcess::Ramp { from_t, to_t, to } => {
                DriftProcess::Ramp { from_t: from_t / speed, to_t: to_t / speed, to }
            }
            DriftProcess::Contention { period, duty, to } => {
                DriftProcess::Contention { period: period / speed, duty, to }
            }
            DriftProcess::Piecewise { t1, first, t2, second } => {
                DriftProcess::Piecewise { t1: t1 / speed, first, t2: t2 / speed, second }
            }
        }
    }

    /// Stable exact-bits encoding for cache keys and seed derivation
    /// (tag word + shape parameters + target bits; distinct per
    /// variant, never reused).
    pub fn key_words(&self) -> Vec<u64> {
        match self {
            DriftProcess::Stationary => vec![0],
            DriftProcess::Step { at, to } => {
                let mut k = vec![1, at.to_bits()];
                k.extend_from_slice(&to.key_bits());
                k
            }
            DriftProcess::Ramp { from_t, to_t, to } => {
                let mut k = vec![2, from_t.to_bits(), to_t.to_bits()];
                k.extend_from_slice(&to.key_bits());
                k
            }
            DriftProcess::Contention { period, duty, to } => {
                let mut k = vec![3, period.to_bits(), duty.to_bits()];
                k.extend_from_slice(&to.key_bits());
                k
            }
            DriftProcess::Piecewise { t1, first, t2, second } => {
                let mut k = vec![4, t1.to_bits()];
                k.extend_from_slice(&first.key_bits());
                k.push(t2.to_bits());
                k.extend_from_slice(&second.key_bits());
                k
            }
        }
    }

    /// Parse a CLI-style drift spec (see [`Self::PARSE_HELP`]). Shape
    /// parameters and multipliers are validated; `None` on any
    /// syntactic or semantic error (the CLI maps it to
    /// `CliError::InvalidValue` with the full grammar, mirroring
    /// `--policy`/`--model`).
    pub fn parse(s: &str) -> Option<DriftProcess> {
        let parsed = if s == "stationary" {
            DriftProcess::Stationary
        } else if let Some(rest) = s.strip_prefix("step:") {
            let (at, fields) = rest.split_once(':')?;
            DriftProcess::Step { at: at.parse().ok()?, to: DriftTargets::parse(fields)? }
        } else if let Some(rest) = s.strip_prefix("ramp:") {
            let (t0, rest) = rest.split_once(':')?;
            let (t1, fields) = rest.split_once(':')?;
            DriftProcess::Ramp {
                from_t: t0.parse().ok()?,
                to_t: t1.parse().ok()?,
                to: DriftTargets::parse(fields)?,
            }
        } else if let Some(rest) = s.strip_prefix("contention:") {
            let (period, rest) = rest.split_once(':')?;
            let (duty, fields) = rest.split_once(':')?;
            DriftProcess::Contention {
                period: period.parse().ok()?,
                duty: duty.parse().ok()?,
                to: DriftTargets::parse(fields)?,
            }
        } else if let Some(rest) = s.strip_prefix("piecewise:") {
            let (t1, rest) = rest.split_once(':')?;
            let (f1, rest) = rest.split_once(':')?;
            let (t2, f2) = rest.split_once(':')?;
            DriftProcess::Piecewise {
                t1: t1.parse().ok()?,
                first: DriftTargets::parse(f1)?,
                t2: t2.parse().ok()?,
                second: DriftTargets::parse(f2)?,
            }
        } else {
            return None;
        };
        parsed.validate().ok()?;
        Some(parsed)
    }

    /// A parseable rendering (round-trips through [`Self::parse`] up to
    /// float formatting); used by figure/CSV labels.
    pub fn render(&self) -> String {
        match self {
            DriftProcess::Stationary => "stationary".into(),
            DriftProcess::Step { at, to } => format!("step:{at}:{}", to.render()),
            DriftProcess::Ramp { from_t, to_t, to } => {
                format!("ramp:{from_t}:{to_t}:{}", to.render())
            }
            DriftProcess::Contention { period, duty, to } => {
                format!("contention:{period}:{duty}:{}", to.render())
            }
            DriftProcess::Piecewise { t1, first, t2, second } => {
                format!("piecewise:{t1}:{}:{t2}:{}", first.render(), second.render())
            }
        }
    }
}

/// A scenario bound to a drift schedule: the deterministic
/// scenario-at-time view of a non-stationary environment.
///
/// Construction validates the schedule *and* that the domain-worst
/// corner of the trajectory still admits a feasible period, so
/// [`Self::scenario_at`] can hand out plain `Scenario` values on the
/// hot path without re-validating (every reachable parameter set lies
/// componentwise between the base and the validated worst corner, and
/// the model's domain gate `b > 0` is monotone in each drifted field).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnvTrajectory {
    base: Scenario,
    drift: DriftProcess,
    /// Cached [`DriftProcess::is_stationary`] — read per phase in the
    /// simulator's hot loop.
    stationary: bool,
}

impl EnvTrajectory {
    pub fn new(base: Scenario, drift: DriftProcess) -> Result<Self, ModelError> {
        drift.validate()?;
        base.validate()?;
        let worst = apply_targets(&base, drift.domain_worst_targets());
        worst.validate()?;
        // The whole trajectory must keep a feasible period, not just a
        // positive-b domain: C(t) < 2 μ(t) b(t) at the worst corner.
        worst.clamp_period(worst.min_period())?;
        Ok(EnvTrajectory { base, drift, stationary: drift.is_stationary() })
    }

    pub fn base(&self) -> &Scenario {
        &self.base
    }

    pub fn drift(&self) -> &DriftProcess {
        &self.drift
    }

    /// Whether every scenario-at-time view equals the base scenario.
    pub fn is_stationary(&self) -> bool {
        self.stationary
    }

    /// The scenario in force at time `t`. Returns the base scenario
    /// **bit-for-bit** when the trajectory is stationary or the
    /// schedule is at the identity at `t` — the zero-regression
    /// contract every consumer's stationary path relies on.
    pub fn scenario_at(&self, t: f64) -> Scenario {
        if self.stationary {
            return self.base;
        }
        let m = self.drift.targets_at(t);
        if m.is_identity() {
            return self.base;
        }
        apply_targets(&self.base, m)
    }

    /// The platform MTBF in force at time `t`.
    pub fn mu_at(&self, t: f64) -> f64 {
        if self.stationary {
            return self.base.mu;
        }
        self.base.mu * self.drift.targets_at(t).mu
    }

    /// The infimum of `μ(t)` over the whole trajectory — the failure
    /// *rate envelope* `λ_max = 1/min_mu` the thinning sampler
    /// proposes at ([`crate::sim::failure`]).
    pub fn min_mu(&self) -> f64 {
        self.base.mu * self.drift.domain_worst_targets().mu
    }

    /// Whether `μ(t)` is constant over the trajectory (the other fields
    /// may still drift). The failure sampler uses this to fall back to
    /// the plain homogeneous stream — bit-identical draws, no thinning
    /// acceptance draws consumed.
    pub fn mu_is_stationary(&self) -> bool {
        self.stationary || self.drift.mu_only().is_stationary()
    }

    /// Exact-bits encoding: the base scenario's canonical
    /// [`Scenario::key_words`] listing (tier-aware; identical to the
    /// historical `key_bits` prefix for scalar scenarios) followed by
    /// the drift schedule's [`DriftProcess::key_words`].
    pub fn key_words(&self) -> Vec<u64> {
        let mut k = self.base.key_words();
        k.extend_from_slice(&self.drift.key_words());
        k
    }
}

/// Apply a multiplier set to a scenario. Plain struct construction —
/// validity is guaranteed by [`EnvTrajectory::new`]'s worst-corner
/// check (the domain gate is monotone in every drifted field).
fn apply_targets(base: &Scenario, m: DriftTargets) -> Scenario {
    let mut s = *base;
    s.ckpt.c = base.ckpt.c * m.c;
    s.ckpt.r = base.ckpt.r * m.r;
    s.mu = base.mu * m.mu;
    s.power.p_io = base.power.p_io * m.p_io;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fig1_scenario;

    const RAMP_TO: DriftTargets = DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 2.0 };
    const DECAY_TO: DriftTargets = DriftTargets { c: 1.0, r: 1.0, mu: 0.4, p_io: 1.0 };

    #[test]
    fn stationary_views_are_bitwise_base() {
        let s = fig1_scenario(300.0, 5.5);
        let traj = EnvTrajectory::new(s, DriftProcess::Stationary).unwrap();
        assert!(traj.is_stationary());
        for t in [0.0, 1.0, 5000.0, 1e9] {
            assert_eq!(traj.scenario_at(t), s);
            assert_eq!(traj.scenario_at(t).key_bits(), s.key_bits());
        }
        assert_eq!(traj.min_mu(), s.mu);
        // Identity targets on a non-trivial shape are stationary too.
        let identity_ramp =
            DriftProcess::Ramp { from_t: 0.0, to_t: 100.0, to: DriftTargets::ONE };
        let traj = EnvTrajectory::new(s, identity_ramp).unwrap();
        assert!(traj.is_stationary());
        assert_eq!(traj.scenario_at(42.0), s);
    }

    #[test]
    fn step_switches_at_the_step_time() {
        let s = fig1_scenario(300.0, 5.5);
        let d = DriftProcess::Step { at: 100.0, to: RAMP_TO };
        let traj = EnvTrajectory::new(s, d).unwrap();
        assert!(!traj.is_stationary());
        assert_eq!(traj.scenario_at(99.9), s);
        let after = traj.scenario_at(100.0);
        assert_eq!(after.ckpt.c, 20.0);
        assert_eq!(after.ckpt.r, 20.0);
        assert_eq!(after.power.p_io, s.power.p_io * 2.0);
        assert_eq!(after.mu, s.mu);
    }

    #[test]
    fn ramp_interpolates_and_holds() {
        let s = fig1_scenario(300.0, 5.5);
        let d = DriftProcess::Ramp { from_t: 1000.0, to_t: 2000.0, to: RAMP_TO };
        let traj = EnvTrajectory::new(s, d).unwrap();
        assert_eq!(traj.scenario_at(0.0), s);
        assert_eq!(traj.scenario_at(1000.0), s);
        let mid = traj.scenario_at(1500.0);
        assert!((mid.ckpt.c - 15.0).abs() < 1e-12, "c={}", mid.ckpt.c);
        let end = traj.scenario_at(2000.0);
        assert_eq!(end.ckpt.c, 20.0);
        assert_eq!(traj.scenario_at(1e6), end);
    }

    #[test]
    fn contention_square_wave() {
        let s = fig1_scenario(300.0, 5.5);
        let d = DriftProcess::Contention { period: 100.0, duty: 0.3, to: RAMP_TO };
        let traj = EnvTrajectory::new(s, d).unwrap();
        assert_eq!(traj.scenario_at(0.0).ckpt.c, 20.0);
        assert_eq!(traj.scenario_at(29.9).ckpt.c, 20.0);
        assert_eq!(traj.scenario_at(30.0), s);
        assert_eq!(traj.scenario_at(99.9), s);
        assert_eq!(traj.scenario_at(100.0).ckpt.c, 20.0);
    }

    #[test]
    fn piecewise_two_segments() {
        let s = fig1_scenario(300.0, 5.5);
        let half = DriftTargets { c: 0.5, r: 0.5, mu: 1.0, p_io: 1.0 };
        let d = DriftProcess::Piecewise { t1: 100.0, first: RAMP_TO, t2: 200.0, second: half };
        let traj = EnvTrajectory::new(s, d).unwrap();
        assert_eq!(traj.scenario_at(50.0), s);
        assert_eq!(traj.scenario_at(150.0).ckpt.c, 20.0);
        assert_eq!(traj.scenario_at(250.0).ckpt.c, 5.0);
    }

    #[test]
    fn mu_drift_and_envelope() {
        let s = fig1_scenario(300.0, 5.5);
        let d = DriftProcess::Ramp { from_t: 0.0, to_t: 1000.0, to: DECAY_TO };
        let traj = EnvTrajectory::new(s, d).unwrap();
        assert!((traj.mu_at(500.0) - 300.0 * 0.7).abs() < 1e-9);
        assert!((traj.min_mu() - 120.0).abs() < 1e-12);
        assert!(!traj.mu_is_stationary());
        // C-only drift keeps mu stationary.
        let c_only = DriftProcess::Step {
            at: 10.0,
            to: DriftTargets { c: 2.0, r: 1.0, mu: 1.0, p_io: 1.0 },
        };
        let traj = EnvTrajectory::new(s, c_only).unwrap();
        assert!(traj.mu_is_stationary());
        assert_eq!(traj.min_mu(), s.mu);
    }

    #[test]
    fn trajectory_rejects_domain_breaking_drift() {
        // mu decaying to 4% of 300 = 12 < D + R + wC = 16: b < 0 at the
        // worst corner.
        let s = fig1_scenario(300.0, 5.5);
        let d = DriftProcess::Step {
            at: 100.0,
            to: DriftTargets { c: 1.0, r: 1.0, mu: 0.04, p_io: 1.0 },
        };
        assert!(EnvTrajectory::new(s, d).is_err());
        // A C stretch past the feasible-period gate fails too.
        let d = DriftProcess::Step {
            at: 100.0,
            to: DriftTargets { c: 60.0, r: 1.0, mu: 0.1, p_io: 1.0 },
        };
        assert!(EnvTrajectory::new(s, d).is_err());
    }

    #[test]
    fn validation_rejects_bad_shapes_and_targets() {
        let bad = DriftTargets { c: 0.0, r: 1.0, mu: 1.0, p_io: 1.0 };
        assert!(bad.validate().is_err());
        assert!(DriftProcess::Step { at: f64::NAN, to: RAMP_TO }.validate().is_err());
        assert!(DriftProcess::Ramp { from_t: 10.0, to_t: 10.0, to: RAMP_TO }
            .validate()
            .is_err());
        assert!(DriftProcess::Contention { period: 0.0, duty: 0.5, to: RAMP_TO }
            .validate()
            .is_err());
        assert!(DriftProcess::Contention { period: 10.0, duty: 1.5, to: RAMP_TO }
            .validate()
            .is_err());
        assert!(
            DriftProcess::Piecewise { t1: 10.0, first: RAMP_TO, t2: 5.0, second: RAMP_TO }
                .validate()
                .is_err()
        );
    }

    #[test]
    fn parse_roundtrips_the_grammar() {
        for (raw, want) in [
            ("stationary", DriftProcess::Stationary),
            (
                "step:3000:c=0.5,r=0.5",
                DriftProcess::Step {
                    at: 3000.0,
                    to: DriftTargets { c: 0.5, r: 0.5, mu: 1.0, p_io: 1.0 },
                },
            ),
            (
                "ramp:0:5000:c=2,r=2,io=2",
                DriftProcess::Ramp { from_t: 0.0, to_t: 5000.0, to: RAMP_TO },
            ),
            (
                "contention:2500:0.4:c=2,r=2,io=2",
                DriftProcess::Contention { period: 2500.0, duty: 0.4, to: RAMP_TO },
            ),
            (
                "piecewise:1000:mu=0.5:2000:mu=0.4",
                DriftProcess::Piecewise {
                    t1: 1000.0,
                    first: DriftTargets { c: 1.0, r: 1.0, mu: 0.5, p_io: 1.0 },
                    t2: 2000.0,
                    second: DECAY_TO,
                },
            ),
        ] {
            assert_eq!(DriftProcess::parse(raw), Some(want), "{raw}");
            let rendered = want.render();
            assert_eq!(DriftProcess::parse(&rendered), Some(want), "{rendered}");
        }
    }

    #[test]
    fn parse_rejects_malformed_and_invalid_specs() {
        for bad in [
            "",
            "bogus",
            "step:100",
            "step:100:",
            "step:100:x=2",
            "step:100:c=0",
            "step:100:c=-2",
            "step:100:c=NaN",
            "step:NaN:c=2",
            "step:100:c=2,c=3",
            "ramp:100:50:c=2",
            "ramp:100:c=2",
            "contention:0:0.5:c=2",
            "contention:100:2:c=2",
            "piecewise:100:c=2:50:c=3",
        ] {
            assert_eq!(DriftProcess::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn mu_only_strips_the_measured_fields() {
        let mixed = DriftProcess::Ramp {
            from_t: 0.0,
            to_t: 100.0,
            to: DriftTargets { c: 2.0, r: 2.0, mu: 0.5, p_io: 2.0 },
        };
        assert_eq!(
            mixed.mu_only(),
            DriftProcess::Ramp {
                from_t: 0.0,
                to_t: 100.0,
                to: DriftTargets { c: 1.0, r: 1.0, mu: 0.5, p_io: 1.0 },
            }
        );
        // A schedule with no μ component strips to (effectively)
        // stationary.
        let c_only = DriftProcess::Step { at: 10.0, to: RAMP_TO };
        assert!(c_only.mu_only().is_stationary());
        assert!(DriftProcess::Stationary.mu_only().is_stationary());
    }

    #[test]
    fn time_scaling_compresses_the_schedule() {
        let d = DriftProcess::Ramp { from_t: 1000.0, to_t: 5000.0, to: RAMP_TO };
        let fast = d.time_scaled(4.0);
        assert_eq!(fast, DriftProcess::Ramp { from_t: 250.0, to_t: 1250.0, to: RAMP_TO });
        let s = fig1_scenario(300.0, 5.5);
        let slow = EnvTrajectory::new(s, d).unwrap();
        let quick = EnvTrajectory::new(s, fast).unwrap();
        assert_eq!(slow.scenario_at(4000.0), quick.scenario_at(1000.0));
    }

    #[test]
    fn key_words_distinguish_schedules() {
        let a = DriftProcess::Step { at: 100.0, to: RAMP_TO };
        let b = DriftProcess::Step { at: 200.0, to: RAMP_TO };
        let c = DriftProcess::Ramp { from_t: 0.0, to_t: 100.0, to: RAMP_TO };
        assert_ne!(a.key_words(), b.key_words());
        assert_ne!(a.key_words(), c.key_words());
        assert_ne!(DriftProcess::Stationary.key_words(), a.key_words());
        // Targets enter the key.
        let d = DriftProcess::Step { at: 100.0, to: DECAY_TO };
        assert_ne!(a.key_words(), d.key_words());
    }
}
