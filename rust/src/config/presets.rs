//! The paper's §4 parameter presets, verbatim.

use crate::model::params::{CheckpointParams, Platform, PowerParams, Scenario};

/// Default application size used when the paper does not pin one: the
/// ratios plotted in the figures are independent of `T_base` (it scales
/// both strategies identically), so any large value works.
pub const DEFAULT_T_BASE_MIN: f64 = 10_000.0;

/// The Jaguar-derived platform of §4: `μ_ind ≈ 125 years`.
pub fn jaguar_platform(n_nodes: f64) -> Platform {
    Platform::new(n_nodes, Platform::jaguar_mu_ind_minutes()).expect("valid platform")
}

/// Fig. 1 / Fig. 2 scenario: `C = R = 10 min`, `D = 1 min`, `γ = 0`,
/// `ω = 1/2`, powers chosen to hit the requested `ρ` at `α = 1`
/// (the paper's `P_Static = P_Cal = 10 mW` nominal point).
pub fn fig1_scenario(mu_min: f64, rho: f64) -> Scenario {
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).expect("valid ckpt");
    let power = PowerParams::from_rho(rho, 1.0, 0.0).expect("valid power");
    Scenario::new(ckpt, power, mu_min, DEFAULT_T_BASE_MIN).expect("valid scenario")
}

/// Fig. 2 is the same parameter family as Fig. 1, scanned over (μ, ρ).
pub fn fig2_scenario(mu_min: f64, rho: f64) -> Scenario {
    fig1_scenario(mu_min, rho)
}

/// Fig. 3 MTBF anchor: `μ = 120 min` at `10⁶` nodes, scaling as `1/N`.
pub const FIG3_MU_AT_1E6_MIN: f64 = 120.0;

/// Fig. 3 scenario: `C = R = 1 min`, `D = 0.1 min`, `γ = 0`, `ω = 1/2`,
/// `μ = 120 min · 10⁶ / N`.
///
/// Returns `None` when the scenario leaves the model's domain (the
/// `N → 10⁸` regime where `μ` falls below the checkpoint overheads —
/// the figures clamp there, which is exactly the paper's
/// "ratios converge to 1" tail).
pub fn fig3_scenario(n_nodes: f64, rho: f64) -> Option<Scenario> {
    let mu = FIG3_MU_AT_1E6_MIN * 1e6 / n_nodes;
    let ckpt = CheckpointParams::new(1.0, 1.0, 0.1, 0.5).ok()?;
    let power = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    Scenario::new(ckpt, power, mu, DEFAULT_T_BASE_MIN).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_matches_paper_parameters() {
        let s = fig1_scenario(300.0, 5.5);
        assert_eq!(s.ckpt.c, 10.0);
        assert_eq!(s.ckpt.r, 10.0);
        assert_eq!(s.ckpt.d, 1.0);
        assert_eq!(s.ckpt.omega, 0.5);
        assert!((s.power.rho() - 5.5).abs() < 1e-12);
        assert!((s.power.alpha() - 1.0).abs() < 1e-12);
        assert_eq!(s.power.gamma(), 0.0);
    }

    #[test]
    fn fig3_mu_scaling() {
        let s6 = fig3_scenario(1e6, 5.5).unwrap();
        assert!((s6.mu - 120.0).abs() < 1e-9);
        let s7 = fig3_scenario(1e7, 5.5).unwrap();
        assert!((s7.mu - 12.0).abs() < 1e-9);
        // 10^8 nodes: mu = 1.2 min, C = 1 min — right at the breakdown.
        // b = 1 - (0.1 + 1 + 0.5)/1.2 < 0 => domain error => None.
        assert!(fig3_scenario(1e8, 5.5).is_none());
        // The largest N that still validates is around 6.3e7.
        assert!(fig3_scenario(5e7, 5.5).is_some());
    }

    #[test]
    fn jaguar_numbers() {
        let p = jaguar_platform(219_150.0);
        assert!((p.mu() - 297.0).abs() < 3.0);
    }
}
