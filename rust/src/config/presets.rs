//! The paper's §4 parameter presets, verbatim — plus the scenario
//! families the grid engine makes cheap to explore: per-node-Weibull
//! Exascale platforms, I/O-contention variants, and a two-level
//! fast/slow checkpoint-cost family (multi-level checkpointing in the
//! spirit of VELOC).

use crate::drift::{DriftProcess, DriftTargets};
use crate::model::params::{CheckpointParams, Platform, PowerParams, Scenario};
use crate::sim::FailureProcess;
use crate::storage::{TierHierarchy, TierSpec};

/// Default application size used when the paper does not pin one: the
/// ratios plotted in the figures are independent of `T_base` (it scales
/// both strategies identically), so any large value works.
pub const DEFAULT_T_BASE_MIN: f64 = 10_000.0;

/// The Jaguar-derived platform of §4: `μ_ind ≈ 125 years`.
pub fn jaguar_platform(n_nodes: f64) -> Platform {
    Platform::new(n_nodes, Platform::jaguar_mu_ind_minutes()).expect("valid platform")
}

/// Fig. 1 / Fig. 2 scenario: `C = R = 10 min`, `D = 1 min`, `γ = 0`,
/// `ω = 1/2`, powers chosen to hit the requested `ρ` at `α = 1`
/// (the paper's `P_Static = P_Cal = 10 mW` nominal point).
pub fn fig1_scenario(mu_min: f64, rho: f64) -> Scenario {
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).expect("valid ckpt");
    let power = PowerParams::from_rho(rho, 1.0, 0.0).expect("valid power");
    Scenario::new(ckpt, power, mu_min, DEFAULT_T_BASE_MIN).expect("valid scenario")
}

/// Fig. 2 is the same parameter family as Fig. 1, scanned over (μ, ρ).
pub fn fig2_scenario(mu_min: f64, rho: f64) -> Scenario {
    fig1_scenario(mu_min, rho)
}

/// Fig. 3 MTBF anchor: `μ = 120 min` at `10⁶` nodes, scaling as `1/N`.
pub const FIG3_MU_AT_1E6_MIN: f64 = 120.0;

/// Fig. 3 scenario: `C = R = 1 min`, `D = 0.1 min`, `γ = 0`, `ω = 1/2`,
/// `μ = 120 min · 10⁶ / N`.
///
/// Returns `None` when the scenario leaves the model's domain (the
/// `N → 10⁸` regime where `μ` falls below the checkpoint overheads —
/// the figures clamp there, which is exactly the paper's
/// "ratios converge to 1" tail).
pub fn fig3_scenario(n_nodes: f64, rho: f64) -> Option<Scenario> {
    let mu = FIG3_MU_AT_1E6_MIN * 1e6 / n_nodes;
    let ckpt = CheckpointParams::new(1.0, 1.0, 0.1, 0.5).ok()?;
    let power = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    Scenario::new(ckpt, power, mu, DEFAULT_T_BASE_MIN).ok()
}

/// Number of per-node renewal streams used to *simulate* a Weibull
/// platform. This is deliberately **not** the scenario's `n_nodes`: by
/// Palm–Khintchine, the superposition of millions of independent
/// renewal streams at fixed aggregate rate converges to Poisson, so a
/// faithful 10⁶-stream simulation would largely wash the Weibull shape
/// back out (besides costing O(N) setup per replicate). Keeping a fixed,
/// modest stream count preserves per-stream burstiness — the scenario is
/// a *bursty-hazard stress model* at the platform's MTBF, answering "how
/// far can the exponential first-order model drift under correlated,
/// infant-mortality-like failures", not "what would exactly N Weibull
/// nodes do".
pub const WEIBULL_SIM_NODES: usize = 256;

/// Bursty-failure stress variant of the Fig. 3 Exascale family
/// (`C = R = 1`, `D = 0.1`, `ω = 1/2`, `μ(N) = 120·10⁶/N` minutes).
///
/// `shape < 1` models the infant-mortality hazard real HPC failure logs
/// show; the per-node Weibull scale is chosen so the *platform* MTBF
/// matches the exponential preset exactly, isolating the effect of the
/// hazard shape. Failures are simulated as [`WEIBULL_SIM_NODES`]
/// superposed streams (see that constant for why the count is fixed
/// rather than `n_nodes`). Returns the scenario plus the
/// [`FailureProcess`] to simulate it under; `None` outside the model's
/// domain (same clamp regime as [`fig3_scenario`]).
pub fn weibull_platform_scenario(
    n_nodes: f64,
    rho: f64,
    shape: f64,
) -> Option<(Scenario, FailureProcess)> {
    assert!(shape > 0.0, "Weibull shape must be positive, got {shape}");
    let scenario = fig3_scenario(n_nodes, rho)?;
    let n = WEIBULL_SIM_NODES;
    // platform_mtbf = scale * Γ(1 + 1/shape) / n  ⇒  solve for scale.
    let scale_ind =
        scenario.mu * n as f64 / crate::sim::failure::gamma(1.0 + 1.0 / shape);
    Some((scenario, FailureProcess::PerNodeWeibull { n, shape, scale_ind }))
}

/// I/O-contention variant of the Fig. 1 family: at contention level
/// `x ≥ 0` the parallel file system is `1 + x` times slower **and**
/// proportionally more power-hungry — `C` and `R` stretch by `1 + x`
/// and `β = P_IO/P_Static` inflates by the same factor (the burst
/// buffer is busy longer *and* draws more). `x = 0` is exactly
/// [`fig1_scenario`].
pub fn io_contention_scenario(mu_min: f64, rho: f64, contention: f64) -> Option<Scenario> {
    assert!(contention >= 0.0, "contention must be >= 0, got {contention}");
    let stretch = 1.0 + contention;
    let ckpt = CheckpointParams::new(10.0 * stretch, 10.0 * stretch, 1.0, 0.5).ok()?;
    let base = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    let power =
        PowerParams::new(base.p_static, base.p_cal, base.p_io * stretch, base.p_down).ok()?;
    Scenario::new(ckpt, power, mu_min, DEFAULT_T_BASE_MIN).ok()
}

/// Two-level fast/slow checkpoint family (VELOC-style multi-level
/// checkpointing collapsed to the paper's single-`C` model): a thin
/// wrapper over the [`crate::storage`] hierarchy that builds the
/// fast/slow [`TierHierarchy`] this family always modelled implicitly,
/// then flattens it with [`flatten_two_level`] at cadence `slow_every`.
/// Fig. 1 powers at the given `ρ`.
pub fn two_level_scenario(
    mu_min: f64,
    rho: f64,
    c_fast: f64,
    c_slow: f64,
    slow_every: usize,
) -> Option<Scenario> {
    assert!(slow_every >= 1, "slow_every must be >= 1");
    assert!(c_slow >= c_fast && c_fast > 0.0, "need 0 < c_fast <= c_slow");
    let power = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    let h = TierHierarchy::new(&[
        TierSpec::new(c_fast, c_fast, power.p_io),
        TierSpec::new(c_slow, c_slow, power.p_io),
    ])
    .ok()?;
    let (c_eff, r_eff) = flatten_two_level(&h, slow_every);
    let ckpt = CheckpointParams::new(c_eff, r_eff, 1.0, 0.5).ok()?;
    Scenario::new(ckpt, power, mu_min, DEFAULT_T_BASE_MIN).ok()
}

/// Collapse a 2-level hierarchy to the paper's scalar model at drain
/// cadence `slow_every`: every `slow_every`-th checkpoint pays the slow
/// level (cost `C_1`), the rest hit the fast level (cost `C_0`), so the
/// *steady-state average* write cost is
/// `((slow_every−1)·C_0 + C_1)/slow_every`. Recovery conservatively
/// reads the slow level (`R = R_1` — the fast tier is lost with the
/// failed node). Returns `(c_eff, r_eff)`.
pub fn flatten_two_level(h: &TierHierarchy, slow_every: usize) -> (f64, f64) {
    assert!(h.len() == 2, "flatten_two_level takes a 2-level hierarchy");
    assert!(slow_every >= 1, "slow_every must be >= 1");
    let c_eff = ((slow_every - 1) as f64 * h.tier(0).c + h.tier(1).c) / slow_every as f64;
    (c_eff, h.tier(1).r)
}

/// Explicit `(α, β, γ)` power-ratio variant of the Fig. 1 checkpoint
/// parameters (`C = R = 10`, `D = 1`, `ω = 1/2`, `P_Static = 1`). The
/// trade-off families sweep this over each ratio axis; `α = 1`,
/// `β = ρ(1+α) − 1`, `γ = 0` recovers [`fig1_scenario`].
pub fn power_ratio_scenario(mu_min: f64, alpha: f64, beta: f64, gamma: f64) -> Option<Scenario> {
    let ckpt = CheckpointParams::new(10.0, 10.0, 1.0, 0.5).ok()?;
    let power = PowerParams::from_ratios(alpha, beta, gamma).ok()?;
    Scenario::new(ckpt, power, mu_min, DEFAULT_T_BASE_MIN).ok()
}

/// Exascale I/O-heavy variant of the Fig. 3 family: checkpoint and
/// recovery stretched by `io_factor ≥ 1` and `β` inflated by the same
/// factor (a saturated parallel file system is busy longer *and* draws
/// more), on the `μ(N) = 120·10⁶/N` platform. `io_factor = 1` is
/// exactly [`fig3_scenario`]. `None` outside the model's domain or for
/// `io_factor < 1` (like every scenario family here, out-of-range
/// corners are skippable, not fatal).
pub fn exascale_io_heavy_scenario(n_nodes: f64, rho: f64, io_factor: f64) -> Option<Scenario> {
    if io_factor < 1.0 {
        return None;
    }
    let mu = FIG3_MU_AT_1E6_MIN * 1e6 / n_nodes;
    let ckpt = CheckpointParams::new(io_factor, io_factor, 0.1, 0.5).ok()?;
    let base = PowerParams::from_rho(rho, 1.0, 0.0).ok()?;
    let power =
        PowerParams::new(base.p_static, base.p_cal, base.p_io * io_factor, base.p_down).ok()?;
    Scenario::new(ckpt, power, mu, DEFAULT_T_BASE_MIN).ok()
}

/// Cartesian power-ratio sweep over `(α, β, γ)` at fixed `μ`, for
/// frontier family grids. Out-of-domain corners are skipped.
pub fn power_ratio_sweep(
    mu_min: f64,
    alphas: &[f64],
    betas: &[f64],
    gammas: &[f64],
) -> Vec<(String, Scenario)> {
    let mut out = Vec::with_capacity(alphas.len() * betas.len() * gammas.len());
    for &alpha in alphas {
        for &beta in betas {
            for &gamma in gammas {
                if let Some(s) = power_ratio_scenario(mu_min, alpha, beta, gamma) {
                    out.push((format!("alpha{alpha}-beta{beta}-gamma{gamma}"), s));
                }
            }
        }
    }
    out
}

/// The named drift families ([`crate::drift`]) the non-stationary
/// experiments ship, timed against [`DEFAULT_T_BASE_MIN`] (compress
/// them with [`DriftProcess::time_scaled`] for the drift-speed axis):
///
/// * `io-ramp` — parallel-file-system contention builds over the first
///   half of the run: `C` and `R` stretch to 2× and the I/O draw
///   inflates with them (the drifting twin of
///   [`io_contention_scenario`]).
/// * `mu-decay` — wear-out: the platform MTBF decays linearly to 40%
///   over the whole run (the μ-side of the VELOC motivation; tracked
///   by the exposure estimator, not the C/R EWMA).
/// * `step-reconfig` — malleable reconfiguration at one third of the
///   run: the checkpoint halves in cost (smaller partition, smaller
///   state), recovery with it.
/// * `contention-burst` — periodic co-scheduled I/O bursts: 2× `C`/`R`
///   and I/O draw during 40% of every 2 500-minute window.
///
/// Every family stays inside the model's domain on every
/// [`tradeoff_presets`] scenario (asserted by the preset tests).
pub fn drift_presets() -> Vec<(&'static str, DriftProcess)> {
    let contention = DriftTargets { c: 2.0, r: 2.0, mu: 1.0, p_io: 2.0 };
    vec![
        (
            "io-ramp",
            DriftProcess::Ramp {
                from_t: 0.0,
                to_t: DEFAULT_T_BASE_MIN / 2.0,
                to: contention,
            },
        ),
        (
            "mu-decay",
            DriftProcess::Ramp {
                from_t: 0.0,
                to_t: DEFAULT_T_BASE_MIN,
                to: DriftTargets { c: 1.0, r: 1.0, mu: 0.4, p_io: 1.0 },
            },
        ),
        (
            "step-reconfig",
            DriftProcess::Step {
                at: DEFAULT_T_BASE_MIN / 3.0,
                to: DriftTargets { c: 0.5, r: 0.5, mu: 1.0, p_io: 1.0 },
            },
        ),
        (
            "contention-burst",
            DriftProcess::Contention { period: 2500.0, duty: 0.4, to: contention },
        ),
    ]
}

/// Look up a [`drift_presets`] family by name (the CLI accepts these on
/// top of the raw [`DriftProcess::parse`] grammar).
pub fn drift_preset(name: &str) -> Option<DriftProcess> {
    drift_presets().into_iter().find(|(n, _)| *n == name).map(|(_, d)| d)
}

/// The named storage-hierarchy presets behind `--tiers` and the tiers
/// figure, fastest first, in the Fig. 1 unit system (`P_Static = 1`,
/// minutes for costs):
///
/// * `tiers-1` — the flattened baseline: everything on the parallel
///   file system (`C = R = 10`, `P_IO = 10`). A single level
///   canonicalises to the scalar model, so on the Fig. 1 powers this
///   reproduces the paper's single-`C` scenarios bit-for-bit.
/// * `tiers-2` — node-local SSD in front of the PFS: cheap, low-draw
///   synchronous writes (`C = 1`, `P_IO = 3`) with background drains
///   to the surviving level.
/// * `tiers-3` — SSD → burst buffer (`C = 2`, `R = 3`, `P_IO = 6`) →
///   PFS.
pub fn tier_presets() -> Vec<(&'static str, Vec<TierSpec>)> {
    let ssd = TierSpec::new(1.0, 1.0, 3.0);
    let bb = TierSpec::new(2.0, 3.0, 6.0);
    let pfs = TierSpec::new(10.0, 10.0, 10.0);
    vec![("tiers-1", vec![pfs]), ("tiers-2", vec![ssd, pfs]), ("tiers-3", vec![ssd, bb, pfs])]
}

/// Look up a [`tier_presets`] hierarchy by name (the CLI accepts these
/// on top of the raw [`crate::storage::parse_tiers`] grammar).
pub fn tier_preset(name: &str) -> Option<Vec<TierSpec>> {
    tier_presets().into_iter().find(|(n, _)| *n == name).map(|(_, t)| t)
}

/// The named trade-off scenario families the Pareto subsystem ships:
/// the paper's two arrow points, one heavy corner per power-ratio axis,
/// and an Exascale I/O-heavy platform. Every preset is inside the
/// model's domain and Monte-Carlo-validated by
/// `rust/tests/pareto_frontier.rs`.
pub fn tradeoff_presets() -> Vec<(&'static str, Scenario)> {
    vec![
        ("fig1-rho5.5", fig1_scenario(300.0, 5.5)),
        ("fig1-rho7", fig1_scenario(300.0, 7.0)),
        (
            "alpha-heavy",
            power_ratio_scenario(300.0, 3.0, 10.0, 0.0).expect("in domain"),
        ),
        (
            "beta-heavy",
            power_ratio_scenario(300.0, 0.5, 15.0, 0.0).expect("in domain"),
        ),
        (
            "gamma-heavy",
            power_ratio_scenario(300.0, 1.0, 10.0, 2.0).expect("in domain"),
        ),
        (
            "exascale-io-heavy",
            exascale_io_heavy_scenario(1e6, 5.5, 2.0).expect("in domain"),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_matches_paper_parameters() {
        let s = fig1_scenario(300.0, 5.5);
        assert_eq!(s.ckpt.c, 10.0);
        assert_eq!(s.ckpt.r, 10.0);
        assert_eq!(s.ckpt.d, 1.0);
        assert_eq!(s.ckpt.omega, 0.5);
        assert!((s.power.rho() - 5.5).abs() < 1e-12);
        assert!((s.power.alpha() - 1.0).abs() < 1e-12);
        assert_eq!(s.power.gamma(), 0.0);
    }

    #[test]
    fn fig3_mu_scaling() {
        let s6 = fig3_scenario(1e6, 5.5).unwrap();
        assert!((s6.mu - 120.0).abs() < 1e-9);
        let s7 = fig3_scenario(1e7, 5.5).unwrap();
        assert!((s7.mu - 12.0).abs() < 1e-9);
        // 10^8 nodes: mu = 1.2 min, C = 1 min — right at the breakdown.
        // b = 1 - (0.1 + 1 + 0.5)/1.2 < 0 => domain error => None.
        assert!(fig3_scenario(1e8, 5.5).is_none());
        // The largest N that still validates is around 6.3e7.
        assert!(fig3_scenario(5e7, 5.5).is_some());
    }

    #[test]
    fn jaguar_numbers() {
        let p = jaguar_platform(219_150.0);
        assert!((p.mu() - 297.0).abs() < 3.0);
    }

    #[test]
    fn weibull_platform_matches_exponential_mtbf() {
        let (s, proc_) = weibull_platform_scenario(1e6, 5.5, 0.7).unwrap();
        assert!((s.mu - 120.0).abs() < 1e-9);
        assert!((proc_.platform_mtbf() - s.mu).abs() / s.mu < 1e-12);
        // shape = 1 degenerates to exponential statistics.
        let (s1, p1) = weibull_platform_scenario(1e6, 5.5, 1.0).unwrap();
        assert!((p1.platform_mtbf() - s1.mu).abs() / s1.mu < 1e-9);
        // Same domain clamp as fig3.
        assert!(weibull_platform_scenario(1e8, 5.5, 0.7).is_none());
    }

    #[test]
    fn io_contention_zero_is_fig1() {
        let a = io_contention_scenario(300.0, 5.5, 0.0).unwrap();
        let b = fig1_scenario(300.0, 5.5);
        assert_eq!(a, b);
    }

    #[test]
    fn io_contention_stretches_cost_and_power() {
        let s = io_contention_scenario(300.0, 5.5, 0.5).unwrap();
        assert!((s.ckpt.c - 15.0).abs() < 1e-12);
        assert!((s.ckpt.r - 15.0).abs() < 1e-12);
        let base = fig1_scenario(300.0, 5.5);
        assert!((s.power.p_io - base.power.p_io * 1.5).abs() < 1e-12);
        // More contention => AlgoE's energy gain grows (costlier I/O).
        let lo = crate::model::ratios::compare(&io_contention_scenario(300.0, 5.5, 0.0).unwrap())
            .unwrap();
        let hi = crate::model::ratios::compare(&s).unwrap();
        assert!(hi.energy_ratio() > lo.energy_ratio());
    }

    #[test]
    fn two_level_effective_cost() {
        // 9 fast (1 min) + 1 slow (10 min) => C_eff = 1.9, R = 10.
        let s = two_level_scenario(300.0, 5.5, 1.0, 10.0, 10).unwrap();
        assert!((s.ckpt.c - 1.9).abs() < 1e-12);
        assert_eq!(s.ckpt.r, 10.0);
        // Cheaper average checkpoints than the single-level slow store.
        let single = fig1_scenario(300.0, 5.5);
        let two = crate::model::ratios::compare(&s).unwrap();
        let one = crate::model::ratios::compare(&single).unwrap();
        assert!(two.makespan_at_t < one.makespan_at_t);
    }

    #[test]
    fn two_level_slow_every_one_is_single_level() {
        let s = two_level_scenario(300.0, 5.5, 1.0, 10.0, 1).unwrap();
        assert_eq!(s.ckpt.c, 10.0);
        assert_eq!(s.ckpt.r, 10.0);
    }

    #[test]
    fn two_level_wrapper_matches_legacy_flatten_bit_for_bit() {
        // The hierarchy-backed wrapper must reproduce the pre-refactor
        // inline expression exactly, not just to tolerance.
        for &(c_fast, c_slow, every) in
            &[(1.0, 10.0, 10usize), (0.7, 9.3, 3), (2.5, 2.5, 1), (1.0, 10.0, 7)]
        {
            let s = two_level_scenario(300.0, 5.5, c_fast, c_slow, every).unwrap();
            let legacy = ((every - 1) as f64 * c_fast + c_slow) / every as f64;
            assert_eq!(s.ckpt.c.to_bits(), legacy.to_bits(), "({c_fast},{c_slow},{every})");
            assert_eq!(s.ckpt.r.to_bits(), c_slow.to_bits());
            // Flattening drops the hierarchy: the family stays scalar.
            assert!(s.tiers.is_scalar());
            let h = TierHierarchy::new(&[
                TierSpec::new(c_fast, c_fast, s.power.p_io),
                TierSpec::new(c_slow, c_slow, s.power.p_io),
            ])
            .unwrap();
            assert_eq!(flatten_two_level(&h, every), (legacy, c_slow));
        }
    }

    #[test]
    fn tier_presets_are_valid_and_layered() {
        let presets = tier_presets();
        assert_eq!(presets.len(), 3);
        assert_eq!(presets[0].0, "tiers-1");
        assert_eq!(presets[1].0, "tiers-2");
        assert_eq!(presets[2].0, "tiers-3");
        for (i, (name, tiers)) in presets.iter().enumerate() {
            assert_eq!(tiers.len(), i + 1, "{name}");
            // Fastest-first: synchronous writes must not get slower
            // than the flattened PFS baseline.
            assert!(tiers[0].c <= tiers[tiers.len() - 1].c, "{name}");
            // Every preset applies cleanly to every trade-off scenario.
            for (label, s) in tradeoff_presets() {
                let t = Scenario::with_tier_specs(s.ckpt, s.power, s.mu, s.t_base, tiers)
                    .unwrap_or_else(|e| panic!("{name} on {label}: {e:?}"));
                assert_eq!(t.hierarchy().is_some(), tiers.len() > 1, "{name} on {label}");
            }
        }
        // tiers-1 on the Fig. 1 powers *is* the Fig. 1 scenario.
        let fig1 = fig1_scenario(300.0, 5.5);
        let flat = Scenario::with_tier_specs(
            fig1.ckpt,
            fig1.power,
            fig1.mu,
            fig1.t_base,
            &tier_preset("tiers-1").unwrap(),
        )
        .unwrap();
        assert_eq!(flat, fig1);
        assert!(tier_preset("bogus").is_none());
    }

    #[test]
    fn power_ratio_scenario_recovers_fig1() {
        // alpha = 1, beta = rho(1+alpha) - 1, gamma = 0 == fig1 at rho.
        let rho = 5.5;
        let beta = rho * 2.0 - 1.0;
        let a = power_ratio_scenario(300.0, 1.0, beta, 0.0).unwrap();
        let b = fig1_scenario(300.0, rho);
        assert_eq!(a, b);
        // Negative ratios are rejected, not panicked on.
        assert!(power_ratio_scenario(300.0, 1.0, -1.0, 0.0).is_none());
    }

    #[test]
    fn exascale_io_heavy_stretches_cost_and_power() {
        let base = fig3_scenario(1e6, 5.5).unwrap();
        let unit = exascale_io_heavy_scenario(1e6, 5.5, 1.0).unwrap();
        assert_eq!(unit, base);
        let heavy = exascale_io_heavy_scenario(1e6, 5.5, 2.0).unwrap();
        assert_eq!(heavy.ckpt.c, 2.0);
        assert_eq!(heavy.ckpt.r, 2.0);
        assert!((heavy.power.p_io - base.power.p_io * 2.0).abs() < 1e-12);
        assert_eq!(heavy.mu, base.mu);
        // Far enough into the breakdown regime the domain closes.
        assert!(exascale_io_heavy_scenario(1e8, 5.5, 2.0).is_none());
        // Out-of-range io_factor is a skippable corner, not a panic.
        assert!(exascale_io_heavy_scenario(1e6, 5.5, 0.5).is_none());
    }

    #[test]
    fn power_ratio_sweep_skips_invalid_corners() {
        let fam = power_ratio_sweep(300.0, &[0.5, 2.0], &[1.0, 10.0], &[0.0, 1.0]);
        assert_eq!(fam.len(), 8);
        assert!(fam.iter().all(|(label, _)| label.starts_with("alpha")));
        // A mu below the overheads empties the family instead of panicking.
        assert!(power_ratio_sweep(10.0, &[1.0], &[10.0], &[0.0]).is_empty());
    }

    #[test]
    fn drift_presets_are_valid_on_every_tradeoff_preset() {
        use crate::drift::EnvTrajectory;
        let families = drift_presets();
        assert!(families.len() >= 4);
        for (name, d) in &families {
            assert!(d.validate().is_ok(), "{name}");
            assert!(!d.is_stationary(), "{name} drifts nothing");
            assert_eq!(drift_preset(name), Some(*d));
            // Valid (worst corner in domain) on every trade-off preset,
            // at unit speed and the figure's fast speed.
            for (label, s) in tradeoff_presets() {
                for speed in [1.0, 4.0] {
                    assert!(
                        EnvTrajectory::new(s, d.time_scaled(speed)).is_ok(),
                        "{name} x{speed} leaves the domain on {label}"
                    );
                }
            }
        }
        assert_eq!(drift_preset("bogus"), None);
    }

    #[test]
    fn tradeoff_presets_are_distinct_and_in_domain() {
        let presets = tradeoff_presets();
        assert!(presets.len() >= 6);
        for (label, s) in &presets {
            assert!(s.validate().is_ok(), "{label}");
            // The trade-off is real: I/O power premium everywhere.
            assert!(s.power.rho() > 1.0, "{label}: rho {}", s.power.rho());
        }
        for i in 0..presets.len() {
            for j in i + 1..presets.len() {
                assert_ne!(presets[i].1, presets[j].1, "{} == {}", presets[i].0, presets[j].0);
            }
        }
    }
}
