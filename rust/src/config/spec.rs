//! JSON scenario specification for user-provided platforms.
//!
//! ```json
//! {
//!   "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
//!   "power": {"p_static": 10.0, "p_cal": 10.0, "p_io": 100.0, "p_down": 0.0},
//!   "platform": {"n_nodes": 1e6, "mu_ind_minutes": 65700000.0},
//!   "t_base_minutes": 10000.0
//! }
//! ```
//!
//! `platform` may be replaced by a direct `"mu_minutes": 120.0`.

use std::path::Path;

use crate::model::params::{CheckpointParams, ModelError, Platform, PowerParams, Scenario};
use crate::util::json::{parse, Json, JsonError};

/// Parsed + validated scenario file.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub scenario: Scenario,
    /// Node count, if the file specified a platform (for reporting).
    pub n_nodes: Option<f64>,
}

#[derive(Debug)]
pub enum SpecError {
    Io(std::io::Error),
    Json(JsonError),
    Model(ModelError),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Io(e) => write!(f, "io error: {e}"),
            SpecError::Json(e) => write!(f, "{e}"),
            SpecError::Model(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SpecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpecError::Io(e) => Some(e),
            SpecError::Json(e) => Some(e),
            SpecError::Model(e) => Some(e),
        }
    }
}

impl From<std::io::Error> for SpecError {
    fn from(e: std::io::Error) -> Self {
        SpecError::Io(e)
    }
}

impl From<JsonError> for SpecError {
    fn from(e: JsonError) -> Self {
        SpecError::Json(e)
    }
}

impl From<ModelError> for SpecError {
    fn from(e: ModelError) -> Self {
        SpecError::Model(e)
    }
}

impl ScenarioSpec {
    pub fn from_file(path: &Path) -> Result<Self, SpecError> {
        Self::from_str(&std::fs::read_to_string(path)?)
    }

    pub fn from_str(raw: &str) -> Result<Self, SpecError> {
        let doc = parse(raw)?;
        let ck = doc
            .get("checkpoint")
            .ok_or_else(|| JsonError::Schema("missing `checkpoint`".into()))?;
        let ckpt = CheckpointParams::new(
            ck.req_f64("c")?,
            ck.req_f64("r")?,
            ck.req_f64("d")?,
            ck.req_f64("omega")?,
        )?;
        let pw = doc
            .get("power")
            .ok_or_else(|| JsonError::Schema("missing `power`".into()))?;
        let power = PowerParams::new(
            pw.req_f64("p_static")?,
            pw.req_f64("p_cal")?,
            pw.req_f64("p_io")?,
            pw.req_f64("p_down")?,
        )?;
        let (mu, n_nodes) = if let Some(pl) = doc.get("platform") {
            let platform =
                Platform::new(pl.req_f64("n_nodes")?, pl.req_f64("mu_ind_minutes")?)?;
            (platform.mu(), Some(platform.n_nodes))
        } else {
            (doc.req_f64("mu_minutes")?, None)
        };
        let t_base = doc.req_f64("t_base_minutes")?;
        Ok(ScenarioSpec { scenario: Scenario::new(ckpt, power, mu, t_base)?, n_nodes })
    }

    /// Serialise back to JSON (round-trip support for tooling).
    pub fn to_json(&self) -> Json {
        let s = &self.scenario;
        let mut fields = vec![
            (
                "checkpoint",
                Json::obj(vec![
                    ("c", Json::Num(s.ckpt.c)),
                    ("r", Json::Num(s.ckpt.r)),
                    ("d", Json::Num(s.ckpt.d)),
                    ("omega", Json::Num(s.ckpt.omega)),
                ]),
            ),
            (
                "power",
                Json::obj(vec![
                    ("p_static", Json::Num(s.power.p_static)),
                    ("p_cal", Json::Num(s.power.p_cal)),
                    ("p_io", Json::Num(s.power.p_io)),
                    ("p_down", Json::Num(s.power.p_down)),
                ]),
            ),
            ("mu_minutes", Json::Num(s.mu)),
            ("t_base_minutes", Json::Num(s.t_base)),
        ];
        if let Some(n) = self.n_nodes {
            fields.push(("n_nodes", Json::Num(n)));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
        "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
        "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
        "mu_minutes": 300.0,
        "t_base_minutes": 10000.0
    }"#;

    #[test]
    fn parses_direct_mu() {
        let spec = ScenarioSpec::from_str(GOOD).unwrap();
        assert_eq!(spec.scenario.mu, 300.0);
        assert!((spec.scenario.power.rho() - 5.5).abs() < 1e-12);
        assert_eq!(spec.n_nodes, None);
    }

    #[test]
    fn parses_platform_form() {
        let raw = r#"{
            "checkpoint": {"c": 1.0, "r": 1.0, "d": 0.1, "omega": 0.5},
            "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
            "platform": {"n_nodes": 1000000, "mu_ind_minutes": 120000000},
            "t_base_minutes": 5000.0
        }"#;
        let spec = ScenarioSpec::from_str(raw).unwrap();
        assert!((spec.scenario.mu - 120.0).abs() < 1e-9);
        assert_eq!(spec.n_nodes, Some(1e6));
    }

    #[test]
    fn rejects_missing_sections_and_bad_values() {
        assert!(ScenarioSpec::from_str("{}").is_err());
        let bad_omega = GOOD.replace("0.5", "1.5");
        assert!(matches!(
            ScenarioSpec::from_str(&bad_omega),
            Err(SpecError::Model(_))
        ));
        let bad_json = &GOOD[..GOOD.len() - 2];
        assert!(matches!(ScenarioSpec::from_str(bad_json), Err(SpecError::Json(_))));
    }

    #[test]
    fn json_roundtrip() {
        let spec = ScenarioSpec::from_str(GOOD).unwrap();
        let text = spec.to_json().to_string_pretty();
        let back = ScenarioSpec::from_str(&text).unwrap();
        assert_eq!(spec.scenario, back.scenario);
    }

    #[test]
    fn file_io() {
        let path = std::env::temp_dir().join("ckpt_spec_test.json");
        std::fs::write(&path, GOOD).unwrap();
        let spec = ScenarioSpec::from_file(&path).unwrap();
        assert_eq!(spec.scenario.t_base, 10_000.0);
        let _ = std::fs::remove_file(path);
    }
}
