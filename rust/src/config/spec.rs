//! JSON scenario specification for user-provided platforms.
//!
//! ```json
//! {
//!   "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
//!   "power": {"p_static": 10.0, "p_cal": 10.0, "p_io": 100.0, "p_down": 0.0},
//!   "platform": {"n_nodes": 1e6, "mu_ind_minutes": 65700000.0},
//!   "t_base_minutes": 10000.0,
//!   "tiers": [
//!     {"c": 1.0, "r": 1.0, "p_io": 30.0},
//!     {"c": 10.0, "r": 10.0, "p_io": 100.0, "retention": 4}
//!   ]
//! }
//! ```
//!
//! `platform` may be replaced by a direct `"mu_minutes": 120.0`. The
//! optional `tiers` array (innermost first) attaches a storage
//! hierarchy; a one-element array canonicalises to the scalar model
//! with that tier's costs ([`Scenario::with_tier_specs`]). Unknown keys
//! — at the top level and inside each tier — are rejected rather than
//! silently ignored: a typo'd `tires` must not quietly produce a scalar
//! scenario on the wire (the serve protocol's strictness contract).

use std::path::Path;

use crate::model::params::{CheckpointParams, ModelError, Platform, PowerParams, Scenario};
use crate::storage::TierSpec;
use crate::util::json::{parse, Json, JsonError};

/// Parsed + validated scenario file.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub scenario: Scenario,
    /// Node count, if the file specified a platform (for reporting).
    pub n_nodes: Option<f64>,
}

#[derive(Debug)]
pub enum SpecError {
    Io(std::io::Error),
    Json(JsonError),
    Model(ModelError),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Io(e) => write!(f, "io error: {e}"),
            SpecError::Json(e) => write!(f, "{e}"),
            SpecError::Model(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SpecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpecError::Io(e) => Some(e),
            SpecError::Json(e) => Some(e),
            SpecError::Model(e) => Some(e),
        }
    }
}

impl From<std::io::Error> for SpecError {
    fn from(e: std::io::Error) -> Self {
        SpecError::Io(e)
    }
}

impl From<JsonError> for SpecError {
    fn from(e: JsonError) -> Self {
        SpecError::Json(e)
    }
}

impl From<ModelError> for SpecError {
    fn from(e: ModelError) -> Self {
        SpecError::Model(e)
    }
}

impl ScenarioSpec {
    pub fn from_file(path: &Path) -> Result<Self, SpecError> {
        Self::from_str(&std::fs::read_to_string(path)?)
    }

    pub fn from_str(raw: &str) -> Result<Self, SpecError> {
        let doc = parse(raw)?;
        if let Json::Obj(m) = &doc {
            for key in m.keys() {
                if !matches!(
                    key.as_str(),
                    "checkpoint"
                        | "power"
                        | "platform"
                        | "mu_minutes"
                        | "t_base_minutes"
                        | "n_nodes"
                        | "tiers"
                ) {
                    return Err(JsonError::Schema(format!(
                        "unknown scenario field `{key}` (expected checkpoint|power|platform|\
                         mu_minutes|t_base_minutes|n_nodes|tiers)"
                    ))
                    .into());
                }
            }
        }
        let ck = doc
            .get("checkpoint")
            .ok_or_else(|| JsonError::Schema("missing `checkpoint`".into()))?;
        let ckpt = CheckpointParams::new(
            ck.req_f64("c")?,
            ck.req_f64("r")?,
            ck.req_f64("d")?,
            ck.req_f64("omega")?,
        )?;
        let pw = doc
            .get("power")
            .ok_or_else(|| JsonError::Schema("missing `power`".into()))?;
        let power = PowerParams::new(
            pw.req_f64("p_static")?,
            pw.req_f64("p_cal")?,
            pw.req_f64("p_io")?,
            pw.req_f64("p_down")?,
        )?;
        let (mu, n_nodes) = if let Some(pl) = doc.get("platform") {
            let platform =
                Platform::new(pl.req_f64("n_nodes")?, pl.req_f64("mu_ind_minutes")?)?;
            (platform.mu(), Some(platform.n_nodes))
        } else {
            (doc.req_f64("mu_minutes")?, None)
        };
        let t_base = doc.req_f64("t_base_minutes")?;
        let scenario = match doc.get("tiers") {
            None => Scenario::new(ckpt, power, mu, t_base)?,
            Some(node) => {
                let specs = parse_tier_array(node)?;
                Scenario::with_tier_specs(ckpt, power, mu, t_base, &specs)?
            }
        };
        Ok(ScenarioSpec { scenario, n_nodes })
    }

    /// Serialise back to JSON (round-trip support for tooling).
    ///
    /// Tiered scenarios emit their `tiers` array, so a serve
    /// [`crate::serve::query::Query`] carrying a hierarchy survives the
    /// wire round-trip with identical solve keys.
    pub fn to_json(&self) -> Json {
        let s = &self.scenario;
        let mut fields = vec![
            (
                "checkpoint",
                Json::obj(vec![
                    ("c", Json::Num(s.ckpt.c)),
                    ("r", Json::Num(s.ckpt.r)),
                    ("d", Json::Num(s.ckpt.d)),
                    ("omega", Json::Num(s.ckpt.omega)),
                ]),
            ),
            (
                "power",
                Json::obj(vec![
                    ("p_static", Json::Num(s.power.p_static)),
                    ("p_cal", Json::Num(s.power.p_cal)),
                    ("p_io", Json::Num(s.power.p_io)),
                    ("p_down", Json::Num(s.power.p_down)),
                ]),
            ),
            ("mu_minutes", Json::Num(s.mu)),
            ("t_base_minutes", Json::Num(s.t_base)),
        ];
        if let Some(n) = self.n_nodes {
            fields.push(("n_nodes", Json::Num(n)));
        }
        if let Some(h) = s.hierarchy() {
            let tiers: Vec<Json> = h
                .iter()
                .map(|t| {
                    let mut tf = vec![
                        ("c", Json::Num(t.c)),
                        ("r", Json::Num(t.r)),
                        ("p_io", Json::Num(t.p_io)),
                    ];
                    if t.capacity > 0 {
                        tf.push(("capacity", Json::Num(t.capacity as f64)));
                    }
                    if t.retention > 0 {
                        tf.push(("retention", Json::Num(t.retention as f64)));
                    }
                    Json::obj(tf)
                })
                .collect();
            fields.push(("tiers", Json::Arr(tiers)));
        }
        Json::obj(fields)
    }
}

/// Parse the `tiers` array: each element is an object with required
/// `c`/`r`/`p_io` and optional integer `capacity`/`retention` (0 =
/// unbounded). Unknown per-tier keys are rejected.
fn parse_tier_array(node: &Json) -> Result<Vec<TierSpec>, SpecError> {
    let items = match node {
        Json::Arr(v) => v,
        _ => return Err(JsonError::Schema("`tiers` must be an array".into()).into()),
    };
    let mut specs = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let obj = match item {
            Json::Obj(m) => m,
            _ => {
                return Err(
                    JsonError::Schema(format!("tiers[{i}] must be an object")).into()
                )
            }
        };
        for key in obj.keys() {
            if !matches!(key.as_str(), "c" | "r" | "p_io" | "capacity" | "retention") {
                return Err(JsonError::Schema(format!(
                    "tiers[{i}]: unknown field `{key}` (expected c|r|p_io|capacity|retention)"
                ))
                .into());
            }
        }
        let bound = |key: &str| -> Result<u32, SpecError> {
            match item.get(key) {
                None => Ok(0),
                Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64 => {
                    Ok(*n as u32)
                }
                Some(other) => Err(JsonError::Schema(format!(
                    "tiers[{i}]: `{key}` must be a non-negative integer, got {other}"
                ))
                .into()),
            }
        };
        specs.push(TierSpec::with_limits(
            item.req_f64("c").map_err(|e| JsonError::Schema(format!("tiers[{i}]: {e}")))?,
            item.req_f64("r").map_err(|e| JsonError::Schema(format!("tiers[{i}]: {e}")))?,
            item.req_f64("p_io").map_err(|e| JsonError::Schema(format!("tiers[{i}]: {e}")))?,
            bound("capacity")?,
            bound("retention")?,
        ));
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
        "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
        "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
        "mu_minutes": 300.0,
        "t_base_minutes": 10000.0
    }"#;

    #[test]
    fn parses_direct_mu() {
        let spec = ScenarioSpec::from_str(GOOD).unwrap();
        assert_eq!(spec.scenario.mu, 300.0);
        assert!((spec.scenario.power.rho() - 5.5).abs() < 1e-12);
        assert_eq!(spec.n_nodes, None);
    }

    #[test]
    fn parses_platform_form() {
        let raw = r#"{
            "checkpoint": {"c": 1.0, "r": 1.0, "d": 0.1, "omega": 0.5},
            "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
            "platform": {"n_nodes": 1000000, "mu_ind_minutes": 120000000},
            "t_base_minutes": 5000.0
        }"#;
        let spec = ScenarioSpec::from_str(raw).unwrap();
        assert!((spec.scenario.mu - 120.0).abs() < 1e-9);
        assert_eq!(spec.n_nodes, Some(1e6));
    }

    #[test]
    fn rejects_missing_sections_and_bad_values() {
        assert!(ScenarioSpec::from_str("{}").is_err());
        let bad_omega = GOOD.replace("0.5", "1.5");
        assert!(matches!(
            ScenarioSpec::from_str(&bad_omega),
            Err(SpecError::Model(_))
        ));
        let bad_json = &GOOD[..GOOD.len() - 2];
        assert!(matches!(ScenarioSpec::from_str(bad_json), Err(SpecError::Json(_))));
    }

    #[test]
    fn json_roundtrip() {
        let spec = ScenarioSpec::from_str(GOOD).unwrap();
        let text = spec.to_json().to_string_pretty();
        let back = ScenarioSpec::from_str(&text).unwrap();
        assert_eq!(spec.scenario, back.scenario);
    }

    #[test]
    fn file_io() {
        let path = std::env::temp_dir().join("ckpt_spec_test.json");
        std::fs::write(&path, GOOD).unwrap();
        let spec = ScenarioSpec::from_file(&path).unwrap();
        assert_eq!(spec.scenario.t_base, 10_000.0);
        let _ = std::fs::remove_file(path);
    }

    const TIERED: &str = r#"{
        "checkpoint": {"c": 10.0, "r": 10.0, "d": 1.0, "omega": 0.5},
        "power": {"p_static": 10, "p_cal": 10, "p_io": 100, "p_down": 0},
        "mu_minutes": 300.0,
        "t_base_minutes": 10000.0,
        "tiers": [
            {"c": 1.0, "r": 1.0, "p_io": 30.0},
            {"c": 10.0, "r": 10.0, "p_io": 100.0, "retention": 4}
        ]
    }"#;

    #[test]
    fn tiered_spec_parses_and_projects_effective_scalars() {
        let spec = ScenarioSpec::from_str(TIERED).unwrap();
        let s = spec.scenario;
        let h = s.hierarchy().expect("hierarchy attached");
        assert_eq!(h.len(), 2);
        assert_eq!(h.tier(1).retention, 4);
        // Effective scalars are the tier projections, not the raw
        // checkpoint block: C = C_0, R = R_1, P_IO = P_IO_0.
        assert_eq!(s.ckpt.c, 1.0);
        assert_eq!(s.ckpt.r, 10.0);
        assert_eq!(s.power.p_io, 30.0);
        // D and ω pass through.
        assert_eq!(s.ckpt.d, 1.0);
        assert_eq!(s.ckpt.omega, 0.5);
    }

    #[test]
    fn single_tier_spec_is_scalar() {
        let one = TIERED.replace(
            r#"{"c": 1.0, "r": 1.0, "p_io": 30.0},
            "#,
            "",
        );
        let spec = ScenarioSpec::from_str(&one).unwrap();
        assert!(spec.scenario.hierarchy().is_none());
        assert_eq!(spec.scenario.ckpt.c, 10.0);
        assert_eq!(spec.scenario.power.p_io, 100.0);
    }

    #[test]
    fn tiered_roundtrip_preserves_solve_identity() {
        let spec = ScenarioSpec::from_str(TIERED).unwrap();
        let back = ScenarioSpec::from_str(&spec.to_json().to_string_pretty()).unwrap();
        assert_eq!(spec.scenario, back.scenario);
        assert_eq!(spec.scenario.key_words(), back.scenario.key_words());
    }

    #[test]
    fn unknown_keys_are_rejected_everywhere() {
        // Top level: a typo'd `tires` must not produce a scalar scenario.
        let top = GOOD.replace("\"mu_minutes\"", "\"tires\": [], \"mu_minutes\"");
        let err = ScenarioSpec::from_str(&top).unwrap_err().to_string();
        assert!(err.contains("unknown scenario field `tires`"), "{err}");
        // Per tier: `io` is the CLI grammar's spelling, not the JSON one.
        let tier = TIERED.replace(r#""p_io": 30.0"#, r#""io": 30.0"#);
        let err = ScenarioSpec::from_str(&tier).unwrap_err().to_string();
        assert!(err.contains("tiers[0]"), "{err}");
        // Invalid tier values surface as model errors.
        let bad = TIERED.replace(r#""c": 1.0"#, r#""c": -1.0"#);
        assert!(ScenarioSpec::from_str(&bad).is_err());
        // Bounds must be non-negative integers.
        let frac = TIERED.replace(r#""retention": 4"#, r#""retention": 1.5"#);
        let err = ScenarioSpec::from_str(&frac).unwrap_err().to_string();
        assert!(err.contains("non-negative integer"), "{err}");
    }
}
