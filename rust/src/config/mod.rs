//! Scenario configuration: the paper's §4 presets plus a JSON loader so
//! users can instantiate the model on their own platforms.
//!
//! * [`presets`] — the exact parameter sets behind Figures 1, 2 and 3.
//! * [`spec`] — [`spec::ScenarioSpec`]: a JSON-serialisable scenario
//!   description with validation (`ckpt-period optimize --config x.json`).

pub mod presets;
pub mod spec;

pub use presets::{fig1_scenario, fig2_scenario, fig3_scenario, jaguar_platform};
pub use spec::ScenarioSpec;
