//! Sharded concurrent map — the shared backing store for every
//! process-wide cache.
//!
//! The three cache families in the crate (the `PureMemo` scalar memos,
//! the grid-cell cache in [`crate::sweep::cache`], and the serve answer
//! cache) all started life as a single global `Mutex<HashMap>`. That is
//! correct — every entry is a pure function of its exact-bits key — but
//! it serialises the 8-thread pool on the hottest path in the process:
//! warm solves that should be a hash lookup queue on one lock.
//!
//! [`ShardedMap`] keeps the same semantics and splits the storage into
//! [`N_SHARDS`] hash-picked shards, each behind its own `Mutex`, so
//! concurrent lookups on different keys proceed in parallel. The shard
//! index is derived from the key with a deterministic fixed-key hasher
//! (`DefaultHasher::new()` — *not* a per-process `RandomState`), so the
//! key→shard assignment is reproducible run to run; which shard holds a
//! value can never influence the value itself, which preserves the
//! crate-wide bit-identical determinism contract at any thread count.
//!
//! Two overflow policies cover the existing caches:
//!
//! * [`ShardedMap::clearing`] — wholesale clear when the total entry
//!   count reaches capacity (the historical `PureMemo` / answer-cache
//!   behaviour: entries are pure functions of their keys, so losing
//!   them only costs recomputation).
//! * [`ShardedMap::fifo`] — global insertion-order FIFO eviction of the
//!   oldest quarter at capacity (the historical `sweep::cache`
//!   behaviour, preserved exactly: one eviction *event* per batch,
//!   `set_capacity` shrinks immediately).
//!
//! Counters are per-shard relaxed atomics aggregated on read, so the
//! unified `MemoStats`/`cache_rows` surfaces keep their exact historical
//! accounting (every lookup resolves to exactly one hit or one miss in
//! the counting modes). Lock contention is observable: when span timing
//! is enabled and an uncontended `try_lock` fails, the blocked wait is
//! recorded in the `ckpt_shard_lock_wait_ns` histogram
//! ([`crate::telemetry::registry::metrics::SHARD_LOCK_WAIT_NS`]) —
//! observational only, never read back into computation.

use std::collections::hash_map::{DefaultHasher, Entry};
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::telemetry::registry::{metrics, timing_enabled};

/// Number of shards. 64 keeps the per-shard mutex essentially
/// uncontended for an 8-thread pool while the whole array stays small
/// enough to iterate for `len`/`clear`/stat aggregation.
pub const N_SHARDS: usize = 64;

/// What to do when an insert finds the map at capacity.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Overflow {
    /// Drop every entry (one `clears` event), then insert.
    Clear,
    /// Evict the globally-oldest quarter in insertion order (one
    /// `evictions` event per batch), then insert.
    EvictQuarter,
}

/// Lock a shard (or the FIFO meta state), recording contended waits in
/// the shard lock-wait histogram. The uncontended path is a bare
/// `try_lock`, so the instrumentation costs nothing unless the lock is
/// actually fought over (and timing is enabled at all).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Cache state is plain data: recover from poisoning like the pool
    // does rather than cascading a worker panic into every reader.
    if timing_enabled() {
        if let Ok(g) = m.try_lock() {
            return g;
        }
        let wait = Instant::now();
        let g = m.lock().unwrap_or_else(|e| e.into_inner());
        metrics::SHARD_LOCK_WAIT_NS.observe(wait.elapsed().as_nanos() as u64);
        return g;
    }
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Shard<K, V> {
    map: Mutex<HashMap<K, V>>,
    /// Mirror of `map.len()`, readable without the lock (for `len`).
    entries: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
}

struct Meta<K> {
    /// Global insertion order for FIFO eviction (unused in clearing
    /// mode). Guarded by its own lock so reads never touch it.
    order: VecDeque<K>,
    /// Current capacity bound ([`ShardedMap::set_capacity`]).
    capacity: usize,
}

struct State<K, V> {
    shards: Vec<Shard<K, V>>,
    meta: Mutex<Meta<K>>,
}

/// A capacity-bounded concurrent map of pure `K -> V` entries, sharded
/// across [`N_SHARDS`] independent locks. Const-constructible so
/// instances can live in `static`s; storage is allocated lazily on
/// first use.
pub struct ShardedMap<K, V> {
    state: OnceLock<State<K, V>>,
    default_capacity: usize,
    overflow: Overflow,
    clears: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Eq + Hash + Clone, V: Clone> ShardedMap<K, V> {
    const fn with_overflow(capacity: usize, overflow: Overflow) -> Self {
        ShardedMap {
            state: OnceLock::new(),
            default_capacity: capacity,
            overflow,
            clears: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Wholesale-clear-at-capacity map (memo semantics).
    pub const fn clearing(capacity: usize) -> Self {
        Self::with_overflow(capacity, Overflow::Clear)
    }

    /// Global-FIFO quarter-eviction map (grid-cache semantics).
    pub const fn fifo(capacity: usize) -> Self {
        Self::with_overflow(capacity, Overflow::EvictQuarter)
    }

    fn state(&self) -> &State<K, V> {
        self.state.get_or_init(|| State {
            shards: (0..N_SHARDS)
                .map(|_| Shard {
                    map: Mutex::new(HashMap::new()),
                    entries: AtomicUsize::new(0),
                    hits: AtomicU64::new(0),
                    misses: AtomicU64::new(0),
                })
                .collect(),
            meta: Mutex::new(Meta { order: VecDeque::new(), capacity: self.default_capacity }),
        })
    }

    /// Deterministic key→shard assignment: `DefaultHasher::new()` is
    /// fixed-key SipHash, so the same key lands on the same shard in
    /// every process (unlike `RandomState`). The shard index is pure
    /// bookkeeping — it can never change a stored value.
    fn shard(&self, key: &K) -> &Shard<K, V> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.state().shards[(h.finish() as usize) & (N_SHARDS - 1)]
    }

    /// Cached value for `key`. Counts a hit on presence and *nothing*
    /// on absence — memo semantics, where a miss is attributed only
    /// once a computed value actually lands ([`Self::count_miss`]), so
    /// failed computes stay invisible to the counters.
    pub fn get(&self, key: &K) -> Option<V> {
        let sh = self.shard(key);
        let v = lock(&sh.map).get(key).cloned();
        if v.is_some() {
            sh.hits.fetch_add(1, Ordering::Relaxed);
        }
        v
    }

    /// Cached value for `key`, counting a hit *or* a miss at lookup
    /// time — grid-cache semantics, where every lookup resolves to
    /// exactly one counter event whether or not a `put` follows.
    pub fn get_counting(&self, key: &K) -> Option<V> {
        let sh = self.shard(key);
        let v = lock(&sh.map).get(key).cloned();
        match &v {
            Some(_) => {
                sh.hits.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                sh.misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        v
    }

    /// Attribute one miss to `key`'s shard (the memo path calls this
    /// after a *successful* compute, just before the insert).
    pub fn count_miss(&self, key: &K) {
        self.shard(key).misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Insert `key → value` unless the key is already present, and
    /// return the winning value: first-writer-wins under concurrency,
    /// so every thread that raced on the same key observes the same
    /// stored value (pure functions of the key make the values equal
    /// anyway; returning the stored one makes it structural). Applies
    /// the overflow policy first when the map is at capacity.
    pub fn insert_if_absent(&self, key: K, value: V) -> V {
        match self.overflow {
            Overflow::Clear => self.insert_clearing(key, value),
            Overflow::EvictQuarter => self.insert_fifo(key, value),
        }
    }

    fn insert_clearing(&self, key: K, value: V) -> V {
        let st = self.state();
        if self.len() >= self.default_capacity {
            for sh in &st.shards {
                lock(&sh.map).clear();
                sh.entries.store(0, Ordering::Relaxed);
            }
            self.clears.fetch_add(1, Ordering::Relaxed);
        }
        let sh = self.shard(&key);
        let mut m = lock(&sh.map);
        match m.entry(key) {
            Entry::Occupied(e) => e.get().clone(),
            Entry::Vacant(e) => {
                e.insert(value.clone());
                sh.entries.fetch_add(1, Ordering::Relaxed);
                value
            }
        }
    }

    fn insert_fifo(&self, key: K, value: V) -> V {
        let st = self.state();
        // Puts serialise on the meta lock (they did on the single global
        // lock before); the win is that *gets* only touch one shard.
        // Lock order is always meta → shard, so gets can never deadlock
        // against an eviction sweep.
        let mut meta = lock(&st.meta);
        if self.len() >= meta.capacity {
            // FIFO eviction of the oldest quarter: amortised, keeps the
            // hot recent working set. One eviction event per batch.
            self.evictions.fetch_add(1, Ordering::Relaxed);
            let batch = (meta.capacity / 4).max(1);
            self.evict_oldest(&mut meta, batch);
        }
        let sh = self.shard(&key);
        let mut m = lock(&sh.map);
        match m.entry(key.clone()) {
            Entry::Occupied(e) => e.get().clone(),
            Entry::Vacant(e) => {
                e.insert(value.clone());
                sh.entries.fetch_add(1, Ordering::Relaxed);
                drop(m);
                meta.order.push_back(key);
                value
            }
        }
    }

    /// Insert or overwrite `key → value` — last-writer-wins, unlike
    /// [`Self::insert_if_absent`]. This is the *hint-store* operation
    /// (e.g. the warm-start argmin hints in
    /// [`crate::model::backend`]): entries are advisory seeds whose
    /// freshest value is the most useful one, not pure functions of
    /// their key, so overwriting is the point. Applies the same
    /// overflow policy as `insert_if_absent`; in FIFO mode the
    /// insertion-order slot is claimed on first insert only (an
    /// overwrite does not refresh recency).
    pub fn put(&self, key: K, value: V) {
        match self.overflow {
            Overflow::Clear => {
                let st = self.state();
                if self.len() >= self.default_capacity {
                    for sh in &st.shards {
                        lock(&sh.map).clear();
                        sh.entries.store(0, Ordering::Relaxed);
                    }
                    self.clears.fetch_add(1, Ordering::Relaxed);
                }
                let sh = self.shard(&key);
                let mut m = lock(&sh.map);
                if m.insert(key, value).is_none() {
                    sh.entries.fetch_add(1, Ordering::Relaxed);
                }
            }
            Overflow::EvictQuarter => {
                let st = self.state();
                let mut meta = lock(&st.meta);
                if self.len() >= meta.capacity {
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    let batch = (meta.capacity / 4).max(1);
                    self.evict_oldest(&mut meta, batch);
                }
                let sh = self.shard(&key);
                let mut m = lock(&sh.map);
                if m.insert(key.clone(), value).is_none() {
                    sh.entries.fetch_add(1, Ordering::Relaxed);
                    drop(m);
                    meta.order.push_back(key);
                }
            }
        }
    }

    /// Pop up to `n` keys off the global FIFO order and remove them
    /// from their shards. Caller holds the meta lock.
    fn evict_oldest(&self, meta: &mut Meta<K>, n: usize) {
        for _ in 0..n {
            match meta.order.pop_front() {
                Some(old) => {
                    let sh = self.shard(&old);
                    if lock(&sh.map).remove(&old).is_some() {
                        sh.entries.fetch_sub(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
    }

    /// Total live entries across every shard (atomic mirrors; no locks).
    pub fn len(&self) -> usize {
        self.state().shards.iter().map(|s| s.entries.load(Ordering::Relaxed)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (tests; cold-start benchmarking). Not counted
    /// as a capacity clear.
    pub fn clear(&self) {
        let st = self.state();
        let mut meta = lock(&st.meta);
        meta.order.clear();
        for sh in &st.shards {
            lock(&sh.map).clear();
            sh.entries.store(0, Ordering::Relaxed);
        }
    }

    /// `(hits, misses)` aggregated over every shard.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.state();
        let hits = st.shards.iter().map(|s| s.hits.load(Ordering::Relaxed)).sum();
        let misses = st.shards.iter().map(|s| s.misses.load(Ordering::Relaxed)).sum();
        (hits, misses)
    }

    /// Wholesale capacity clears (clearing mode).
    pub fn clears(&self) -> u64 {
        self.clears.load(Ordering::Relaxed)
    }

    /// FIFO eviction events — one per oldest-quarter batch (fifo mode).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Zero the hit/miss counters (benches bracket phases with this).
    /// Clear/eviction event counts are left alone, matching the
    /// historical `sweep::cache::reset_stats` behaviour.
    pub fn reset_stats(&self) {
        for sh in &self.state().shards {
            sh.hits.store(0, Ordering::Relaxed);
            sh.misses.store(0, Ordering::Relaxed);
        }
    }

    /// Override the capacity bound (tests/benches exercising eviction;
    /// restore the construction-time default afterwards). In fifo mode,
    /// shrinking below the current size evicts FIFO immediately;
    /// clearing-mode maps keep their construction-time capacity.
    pub fn set_capacity(&self, cap: usize) {
        let st = self.state();
        let mut meta = lock(&st.meta);
        meta.capacity = cap.max(1);
        while self.len() > meta.capacity {
            if meta.order.is_empty() {
                break;
            }
            self.evict_oldest(&mut meta, 1);
        }
    }

    /// The construction-time capacity bound (`set_capacity`'s restore
    /// value).
    pub fn default_capacity(&self) -> usize {
        self.default_capacity
    }

    /// Live entries per shard, in shard order — the
    /// `ckpt_cache_shard_entries` exposition family reads this.
    pub fn shard_entries(&self) -> Vec<usize> {
        self.state().shards.iter().map(|s| s.entries.load(Ordering::Relaxed)).collect()
    }

    /// `(hits, misses)` per shard, in shard order (the concurrency
    /// proptest asserts these sum to exactly the aggregate).
    pub fn shard_stats(&self) -> Vec<(u64, u64)> {
        self.state()
            .shards
            .iter()
            .map(|s| (s.hits.load(Ordering::Relaxed), s.misses.load(Ordering::Relaxed)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_insert_and_memo_counting() {
        static MAP: ShardedMap<u64, f64> = ShardedMap::clearing(16);
        assert_eq!(MAP.get(&1), None);
        assert_eq!(MAP.stats(), (0, 0)); // plain get never counts a miss
        MAP.count_miss(&1);
        assert_eq!(MAP.insert_if_absent(1, 42.0), 42.0);
        assert_eq!(MAP.get(&1), Some(42.0));
        assert_eq!(MAP.stats(), (1, 1));
        // First writer wins: a losing racer reads back the stored value.
        assert_eq!(MAP.insert_if_absent(1, 99.0), 42.0);
        assert_eq!(MAP.get(&1), Some(42.0));
        assert_eq!(MAP.len(), 1);
        let per_shard: u64 = MAP.shard_stats().iter().map(|(h, m)| h + m).sum();
        let (hits, misses) = MAP.stats();
        assert_eq!(per_shard, hits + misses);
    }

    #[test]
    fn put_overwrites_where_insert_if_absent_does_not() {
        static MAP: ShardedMap<u64, f64> = ShardedMap::clearing(16);
        MAP.put(1, 10.0);
        assert_eq!((MAP.get(&1), MAP.len()), (Some(10.0), 1));
        MAP.put(1, 20.0);
        assert_eq!((MAP.get(&1), MAP.len()), (Some(20.0), 1), "put overwrites in place");
        assert_eq!(MAP.insert_if_absent(1, 30.0), 20.0, "first-writer-wins still holds");

        static FIFO: ShardedMap<u64, f64> = ShardedMap::fifo(8);
        for k in 0..8 {
            FIFO.put(k, k as f64);
        }
        FIFO.put(3, 33.0);
        assert_eq!((FIFO.get(&3), FIFO.len()), (Some(33.0), 8), "overwrite adds no entry");
        FIFO.put(8, 8.0);
        assert_eq!(FIFO.evictions(), 1, "capacity put still evicts FIFO");
        assert_eq!(FIFO.get(&0), None);
    }

    #[test]
    fn clearing_mode_clears_wholesale_at_capacity() {
        static MAP: ShardedMap<u64, f64> = ShardedMap::clearing(4);
        for k in 0..4 {
            MAP.insert_if_absent(k, k as f64);
        }
        assert_eq!((MAP.len(), MAP.clears()), (4, 0));
        MAP.insert_if_absent(100, 100.0);
        assert_eq!((MAP.len(), MAP.clears()), (1, 1));
        assert_eq!(MAP.get(&100), Some(100.0));
        assert_eq!(MAP.get(&0), None);
    }

    #[test]
    fn fifo_mode_evicts_oldest_quarter_and_shrinks_on_set_capacity() {
        static MAP: ShardedMap<u64, f64> = ShardedMap::fifo(16);
        for k in 0..16 {
            MAP.insert_if_absent(k, k as f64);
        }
        assert_eq!((MAP.len(), MAP.evictions()), (16, 0));
        // At capacity: one eviction event drops the oldest quarter.
        MAP.insert_if_absent(16, 16.0);
        assert_eq!((MAP.len(), MAP.evictions()), (13, 1));
        for k in 0..4 {
            assert_eq!(MAP.get(&k), None, "oldest quarter should be gone");
        }
        assert_eq!(MAP.get(&4), Some(4.0));
        assert_eq!(MAP.get(&16), Some(16.0));
        // Shrinking evicts FIFO immediately without an eviction event.
        MAP.set_capacity(4);
        assert_eq!((MAP.len(), MAP.evictions()), (4, 1));
        assert_eq!(MAP.get(&16), Some(16.0), "newest entry survives the shrink");
        MAP.set_capacity(MAP.default_capacity());
        assert_eq!(MAP.default_capacity(), 16);
    }

    #[test]
    fn shard_assignment_is_deterministic_and_spread() {
        static MAP: ShardedMap<u64, f64> = ShardedMap::clearing(1 << 12);
        for k in 0..512 {
            MAP.insert_if_absent(k, k as f64);
        }
        let occupancy = MAP.shard_entries();
        assert_eq!(occupancy.len(), N_SHARDS);
        assert_eq!(occupancy.iter().sum::<usize>(), 512);
        // SipHash spreads 512 sequential keys over far more than one
        // shard; exact counts are pinned by determinism, spread by hash
        // quality.
        let occupied = occupancy.iter().filter(|&&n| n > 0).count();
        assert!(occupied > N_SHARDS / 2, "only {occupied} shards occupied");
        let again: Vec<usize> = MAP.shard_entries();
        assert_eq!(occupancy, again);
    }
}
