//! Streaming statistics, confidence intervals, and percentiles.
//!
//! Used by the Monte-Carlo simulator (replicate means with Student-t
//! confidence intervals), by the bench harness (median / p10 / p90), and
//! by the coordinator's metrics.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Two-sided confidence half-width at the given level using the
    /// Student-t quantile.
    pub fn ci_half_width(&self, level: ConfidenceLevel) -> f64 {
        if self.n < 2 {
            return f64::INFINITY;
        }
        t_quantile(level, self.n - 1) * self.sem()
    }

    /// `(lo, hi)` confidence interval for the mean.
    pub fn ci(&self, level: ConfidenceLevel) -> (f64, f64) {
        let h = self.ci_half_width(level);
        (self.mean - h, self.mean + h)
    }
}

/// Supported confidence levels for [`OnlineStats::ci`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfidenceLevel {
    P90,
    P95,
    P99,
}

/// Two-sided Student-t quantile for `df` degrees of freedom.
///
/// Exact table for small df, asymptotic normal quantile with a
/// Cornish–Fisher-style 1/df correction beyond the table — accurate to
/// ~1e-3 over the df range the simulator uses (≥ 10 replicates).
fn t_quantile(level: ConfidenceLevel, df: u64) -> f64 {
    // Rows: df 1..=30; columns chosen per level.
    const T95: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    const T90: [f64; 30] = [
        6.314, 2.920, 2.353, 2.132, 2.015, 1.943, 1.895, 1.860, 1.833, 1.812, 1.796, 1.782,
        1.771, 1.761, 1.753, 1.746, 1.740, 1.734, 1.729, 1.725, 1.721, 1.717, 1.714, 1.711,
        1.708, 1.706, 1.703, 1.701, 1.699, 1.697,
    ];
    const T99: [f64; 30] = [
        63.657, 9.925, 5.841, 4.604, 4.032, 3.707, 3.499, 3.355, 3.250, 3.169, 3.106, 3.055,
        3.012, 2.977, 2.947, 2.921, 2.898, 2.878, 2.861, 2.845, 2.831, 2.819, 2.807, 2.797,
        2.787, 2.779, 2.771, 2.763, 2.756, 2.750,
    ];
    let (table, z, c1): (&[f64; 30], f64, f64) = match level {
        ConfidenceLevel::P90 => (&T90, 1.6449, 0.85),
        ConfidenceLevel::P95 => (&T95, 1.9600, 1.21),
        ConfidenceLevel::P99 => (&T99, 2.5758, 2.54),
    };
    if df == 0 {
        return f64::INFINITY;
    }
    if df <= 30 {
        table[(df - 1) as usize]
    } else {
        // z + c1/df captures the leading 1/df term of the t quantile.
        z + c1 / df as f64
    }
}

/// Percentile of a sample (linear interpolation between order statistics,
/// `q` in `[0, 1]`). Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median, via [`percentile`].
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Relative error |a-b| / max(|a|,|b|,eps); symmetric, safe near zero.
pub fn rel_err(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

/// Simple fixed-width histogram for diagnostics.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basics() {
        let mut s = OnlineStats::new();
        s.extend([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_single_value() {
        let mut s = OnlineStats::new();
        s.push(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.variance(), 0.0);
        assert!(s.ci_half_width(ConfidenceLevel::P95).is_infinite());
    }

    #[test]
    fn ci_contains_true_mean_usually() {
        // 95% CI over repeated uniform samples should contain 0.5 ~95% of
        // the time; with 200 trials allow a generous band.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(1234);
        let mut hits = 0;
        let trials = 200;
        for _ in 0..trials {
            let mut s = OnlineStats::new();
            for _ in 0..50 {
                s.push(rng.uniform());
            }
            let (lo, hi) = s.ci(ConfidenceLevel::P95);
            if lo <= 0.5 && 0.5 <= hi {
                hits += 1;
            }
        }
        assert!(hits >= 180, "hits={hits}/{trials}");
    }

    #[test]
    fn t_quantile_matches_table_and_asymptote() {
        assert!((t_quantile(ConfidenceLevel::P95, 1) - 12.706).abs() < 1e-9);
        assert!((t_quantile(ConfidenceLevel::P95, 30) - 2.042).abs() < 1e-9);
        // large df → z
        assert!((t_quantile(ConfidenceLevel::P95, 1_000_000) - 1.96).abs() < 1e-3);
        assert!(t_quantile(ConfidenceLevel::P99, 5) > t_quantile(ConfidenceLevel::P95, 5));
        assert!(t_quantile(ConfidenceLevel::P95, 5) > t_quantile(ConfidenceLevel::P90, 5));
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn rel_err_props() {
        assert_eq!(rel_err(1.0, 1.0), 0.0);
        assert!((rel_err(1.0, 1.1) - rel_err(1.1, 1.0)).abs() < 1e-15);
        assert!(rel_err(0.0, 0.0) == 0.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins(), &[1; 10]);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 12);
    }
}
