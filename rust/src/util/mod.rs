//! Self-contained utility layer.
//!
//! The build environment is fully offline and the vendored crate set has
//! no `rand`, `serde`, `criterion` or `proptest`, so this module provides
//! the small, well-tested subset of those that the rest of the crate
//! needs: a seedable PCG PRNG with the usual distributions
//! ([`rng`]), streaming statistics and confidence intervals ([`stats`]),
//! a minimal JSON reader/writer ([`json`]), a tiny property-based testing
//! harness ([`proptest`]), a timing harness for the `harness = false`
//! benches ([`bench`]), an ASCII table printer ([`table`]), a
//! process-wide pure-function memo ([`memo`]), the sharded concurrent
//! map every process-wide cache is built on ([`shard`]), and a
//! persistent work-stealing thread pool ([`pool`]) that the Monte-Carlo
//! runner and the scenario-grid engine fan out on.

pub mod bench;
pub mod json;
pub mod memo;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod shard;
pub mod stats;
pub mod table;
