//! A miniature property-based testing harness.
//!
//! No `proptest`/`quickcheck` exists in the offline vendor set, so this
//! module provides the 10% we need: seeded generators over the crate's
//! parameter spaces and an N-case `check` loop that reports the failing
//! seed and case. There is no shrinking — cases are drawn from already
//! small, interpretable spaces (model parameters), so the raw failing
//! case is directly debuggable.
//!
//! Usage (`no_run` because rustdoc test binaries don't inherit the
//! xla_extension rpath; the same pattern runs for real in every
//! `#[test]` below):
//! ```no_run
//! use ckpt_period::prop_assert;
//! use ckpt_period::util::proptest::{check, Gen};
//! check("sum is commutative", 500, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     prop_assert!(g, a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Pcg64,
    /// Human-readable trace of drawn values, printed on failure.
    trace: Vec<String>,
    case: usize,
}

impl Gen {
    fn new(seed: u64, case: usize) -> Self {
        Gen { rng: Pcg64::new(seed, case as u64), trace: Vec::new(), case }
    }

    /// Current case index (0-based).
    pub fn case(&self) -> usize {
        self.case
    }

    /// Draw a uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(format!("f64_in({lo},{hi})={v}"));
        v
    }

    /// Draw a log-uniform f64 in [lo, hi): equal mass per decade.
    /// The natural draw for scale parameters (MTBF, node counts).
    pub fn f64_log_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        let v = (self.rng.uniform_in(lo.ln(), hi.ln())).exp();
        self.trace.push(format!("f64_log_in({lo},{hi})={v}"));
        v
    }

    /// Draw a uniform integer in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let v = lo + self.rng.below((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    /// Draw a boolean.
    pub fn bool(&mut self) -> bool {
        let v = self.rng.uniform() < 0.5;
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        self.trace.push(format!("choose(idx={i})"));
        &xs[i]
    }

    /// Underlying RNG, for drawing domain objects directly.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Record a named value in the failure trace.
    pub fn note(&mut self, name: &str, value: impl std::fmt::Display) {
        self.trace.push(format!("{name}={value}"));
    }
}

/// A property failure: message plus the generator trace.
#[derive(Debug)]
pub struct PropError(pub String);

/// Result type returned by properties.
pub type PropResult = Result<(), PropError>;

/// Assert inside a property, capturing the generator trace on failure.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err($crate::util::proptest::PropError(format!($($fmt)*)));
        }
    };
}
pub use prop_assert;

/// Environment knob: `CKPT_PROPTEST_SEED` overrides the default seed so a
/// failing run can be replayed exactly.
fn base_seed() -> u64 {
    std::env::var("CKPT_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_2013)
}

/// Run `cases` random cases of `prop`; panic with seed + trace on the
/// first failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(PropError(msg)) = prop(&mut g) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay with CKPT_PROPTEST_SEED={seed}):\n  {msg}\n  trace: {}",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("tautology", 100, |g| {
            let x = g.f64_in(0.0, 1.0);
            n += 1;
            prop_assert!(g, (0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
        assert_eq!(n, 100);
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!(g, x > 2.0, "x={x} not > 2");
            Ok(())
        });
    }

    #[test]
    fn log_uniform_within_bounds() {
        check("log-uniform bounds", 300, |g| {
            let v = g.f64_log_in(1e-3, 1e6);
            prop_assert!(g, (1e-3..1e6).contains(&v), "v={v}");
            Ok(())
        });
    }

    #[test]
    fn usize_in_bounds_inclusive() {
        let mut seen_lo = false;
        let mut seen_hi = false;
        check("usize bounds", 500, |g| {
            let v = g.usize_in(3, 7);
            seen_lo |= v == 3;
            seen_hi |= v == 7;
            prop_assert!(g, (3..=7).contains(&v), "v={v}");
            Ok(())
        });
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn choose_covers_all() {
        let xs = [1, 2, 3];
        let mut seen = [false; 3];
        check("choose coverage", 200, |g| {
            let v = *g.choose(&xs);
            seen[(v - 1) as usize] = true;
            Ok(())
        });
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        check("record", 20, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 20, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
