//! Process-wide memo for pure scalar functions of an exact-bits key.
//!
//! Two subsystems memoise `f64` values that are pure functions of a
//! small fixed-size key: the exact backend's numeric optima
//! ([`crate::model::backend`]) and the online policy periods
//! ([`crate::pareto::online`]). Both need the same contract — lazily
//! initialised process-wide storage, compute-outside-the-lock (a
//! concurrent miss on the same key just recomputes the same pure
//! value), and wholesale clearing at a capacity bound (entries are pure
//! functions of their key, so losing them only costs recomputation).
//! [`PureMemo`] is that contract, once, instead of a hand-rolled copy
//! per call site. (The grid engine's [`crate::sweep::cache`] is the
//! heavyweight sibling: structured values, hit/miss counters, tunable
//! capacity.) Storage is a [`ShardedMap`] — 64 hash-picked shards,
//! each behind its own mutex — so warm lookups on different keys no
//! longer serialise on one global lock, and a miss inserts
//! first-writer-wins instead of overwriting.
//!
//! Each memo carries hit/miss/clear counters ([`PureMemo::stats`],
//! mirroring `sweep::cache::stats`): drift trajectories re-key the
//! online memo far more often than stationary runs (every distinct
//! quantised `(C, R, μ)` along the schedule is an entry), and the
//! `info` subcommand surfaces the churn instead of leaving it
//! invisible.
//!
//! Because values are pure functions of their keys, which thread (or
//! concurrently running grid cell) fills an entry first cannot change
//! the value anyone reads — the property every thread-count-invariance
//! test in the crate leans on.

use std::convert::Infallible;
use std::hash::Hash;

use super::shard::ShardedMap;

/// Counter snapshot of one [`PureMemo`] (since process start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    /// Wholesale capacity clears — the churn signal: a non-zero count
    /// means the working set outgrew the memo and entries are being
    /// recomputed.
    pub clears: u64,
}

impl MemoStats {
    /// Hit fraction in `[0, 1]` (`0` before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A capacity-bounded memo for a pure `K -> V` function (`V = f64` by
/// default — the historical shape; the tier-plan memo stores a small
/// plan struct instead). Keys only need `Clone`, so variable-length
/// `Vec<u64>` keys (scenarios with tier extensions) work too.
pub struct PureMemo<K, V = f64> {
    map: ShardedMap<K, V>,
}

impl<K: Eq + Hash + Clone, V: Clone> PureMemo<K, V> {
    /// Const-constructible so instances can live in `static`s.
    pub const fn new(capacity: usize) -> Self {
        PureMemo { map: ShardedMap::clearing(capacity) }
    }

    /// Cached value for `key`, computing (and caching) it on a miss.
    /// `compute` errors pass through and nothing is cached (errors do
    /// not count as misses either: the counters track memo behaviour,
    /// not domain validity).
    pub fn get_or_try_compute<E>(
        &self,
        key: K,
        compute: impl FnOnce() -> Result<V, E>,
    ) -> Result<V, E> {
        if let Some(v) = self.map.get(&key) {
            return Ok(v);
        }
        // Compute outside the lock: a concurrent miss on the same key
        // just recomputes the same pure value. The insert is
        // insert-if-absent, so the first writer wins and a losing racer
        // returns the stored value — stats stay coherent (exactly one
        // hit *or* one miss per resolved lookup) and nobody overwrites
        // an entry that a hit could be concurrently reading.
        let v = compute()?;
        self.map.count_miss(&key);
        Ok(self.map.insert_if_absent(key, v))
    }

    /// Infallible variant of [`Self::get_or_try_compute`].
    pub fn get_or_compute(&self, key: K, compute: impl FnOnce() -> V) -> V {
        self.get_or_try_compute::<Infallible>(key, || Ok(compute()))
            .unwrap_or_else(|e| match e {})
    }

    /// Number of live entries (test/diagnostic use).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss/clear counters since process start.
    pub fn stats(&self) -> MemoStats {
        let (hits, misses) = self.map.stats();
        MemoStats { hits, misses, clears: self.map.clears() }
    }

    /// Live entries per backing shard ([`ShardedMap::shard_entries`] —
    /// the `ckpt_cache_shard_entries` exposition family).
    pub fn shard_entries(&self) -> Vec<usize> {
        self.map.shard_entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_and_reuses_values() {
        static MEMO: PureMemo<u64> = PureMemo::new(16);
        let mut calls = 0;
        let a = MEMO.get_or_compute(1, || {
            calls += 1;
            42.0
        });
        let b = MEMO.get_or_compute(1, || {
            calls += 1;
            99.0 // must not be observed: the entry is already cached
        });
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(a, 42.0);
        assert_eq!(calls, 1);
        let st = MEMO.stats();
        assert_eq!((st.hits, st.misses, st.clears), (1, 1, 0));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn errors_pass_through_and_cache_nothing() {
        static MEMO: PureMemo<u64> = PureMemo::new(16);
        let r: Result<f64, &str> = MEMO.get_or_try_compute(7, || Err("nope"));
        assert_eq!(r, Err("nope"));
        // Errors are neither hits nor misses.
        assert_eq!(MEMO.stats(), MemoStats::default());
        // The failed key is not cached; a later success fills it.
        let v = MEMO.get_or_try_compute::<&str>(7, || Ok(3.5)).unwrap();
        assert_eq!(v, 3.5);
        assert_eq!(MEMO.stats().misses, 1);
    }

    #[test]
    fn capacity_overflow_clears_wholesale_and_counts() {
        static MEMO: PureMemo<u64> = PureMemo::new(4);
        for k in 0..4 {
            MEMO.get_or_compute(k, || k as f64);
        }
        assert_eq!(MEMO.len(), 4);
        assert_eq!(MEMO.stats().clears, 0);
        // At capacity the next insert clears first.
        MEMO.get_or_compute(100, || 100.0);
        assert_eq!(MEMO.len(), 1);
        assert_eq!(MEMO.stats().clears, 1);
        // Cleared entries simply recompute.
        assert_eq!(MEMO.get_or_compute(0, || -1.0), -1.0);
        assert_eq!(MEMO.stats().misses, 6);
    }
}
