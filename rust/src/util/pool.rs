//! Persistent work-stealing thread pool (std-only).
//!
//! The seed paid a thread spawn + join (~100 µs) on **every**
//! `monte_carlo` call, which capped grid throughput long before the
//! simulator did. This pool spawns its workers once per process
//! ([`ThreadPool::global`]) and then executes *batches* of indexed tasks
//! with no per-call thread churn:
//!
//! * Each batch partitions indices `0..n` into contiguous per-worker
//!   deques. Workers pop from the front of their own deque and, when
//!   empty, **steal the back half** of a victim's deque — classic
//!   work-stealing, so ragged cell costs (e.g. Monte-Carlo cells next to
//!   closed-form cells) still load-balance.
//! * The submitting thread participates in its own batch, so a
//!   single-threaded caller never blocks behind idle workers.
//! * Results are written by index ([`ThreadPool::map`]), so the output is
//!   **byte-identical for every thread count** — determinism lives in the
//!   task seeds, not the schedule.
//! * Nested calls from inside a worker degrade to inline sequential
//!   execution ([`ThreadPool::in_worker`]) instead of deadlocking; the
//!   simulator's Monte-Carlo fan-out relies on this when it runs as a
//!   grid cell.
//!
//! One batch runs at a time; concurrent submitters queue on a mutex.
//! Worker panics are caught, the batch is drained, and the panic is
//! re-raised on the submitting thread.
//!
//! The pool reports saturation through the telemetry registry: batch
//! queue depth at submit, steal count, a per-job latency histogram and
//! per-participant busy time (`ckpt_pool_*` families). All of it is
//! observational — the scheduler never reads a metric back, so results
//! stay byte-identical with telemetry on or off.

use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::telemetry::registry::metrics::{
    POOL_BATCHES_TOTAL, POOL_JOBS_TOTAL, POOL_JOB_NS, POOL_QUEUE_DEPTH, POOL_STEALS_TOTAL,
    POOL_WORKER_BUSY_NS,
};
use crate::telemetry::registry::{timing_enabled, MAX_WORKER_SLOTS};

/// Type-erased `&'static dyn Fn(usize)` for the current batch. The
/// lifetime is a lie the pool keeps honest: [`ThreadPool::run`] does not
/// return until every task of the batch has finished, so the borrow the
/// caller handed in outlives every use.
#[derive(Clone, Copy)]
struct RawTask(&'static (dyn Fn(usize) + Sync));

#[derive(Clone)]
struct BatchHandles {
    queues: Arc<Vec<Mutex<VecDeque<usize>>>>,
    task: RawTask,
    remaining: Arc<AtomicUsize>,
    panicked: Arc<AtomicBool>,
}

struct State {
    /// Monotone batch counter: workers key their waits on it.
    epoch: u64,
    batch: Option<BatchHandles>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    batch_done: Condvar,
}

/// The pool. Construct once ([`ThreadPool::global`]) and reuse.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Serialises batches (one at a time).
    batch_lock: Mutex<()>,
}

std::thread_local! {
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Poison-tolerant lock: a panic that unwound through a guard elsewhere
/// must not wedge the pool (we propagate task panics explicitly).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a, T>(cv: &Condvar, g: std::sync::MutexGuard<'a, T>) -> std::sync::MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

impl ThreadPool {
    /// Pool with `threads` workers (the submitting thread always helps,
    /// so `threads = 0` still makes progress, inline).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, batch: None, shutdown: false }),
            work_ready: Condvar::new(),
            batch_done: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads);
        for w in 0..threads {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("ckpt-pool-{w}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    worker_loop(&shared, w);
                })
                .expect("spawn pool worker");
            workers.push(handle);
        }
        ThreadPool { shared, workers, batch_lock: Mutex::new(()) }
    }

    /// The process-wide pool, created on first use with one worker per
    /// available core (override with `CKPT_POOL_THREADS`).
    pub fn global() -> &'static ThreadPool {
        static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let n = std::env::var("CKPT_POOL_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                });
            // The submitter participates too, so n-1 workers saturate n
            // cores — and CKPT_POOL_THREADS=1 means genuinely serial
            // (zero workers: `run` takes the inline path).
            ThreadPool::new(n.saturating_sub(1))
        })
    }

    /// Worker count (excluding the submitting thread).
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// True on a pool worker thread. Nested parallel calls must run
    /// inline (the pool executes one batch at a time).
    pub fn in_worker() -> bool {
        IN_POOL_WORKER.with(|f| f.get())
    }

    /// Run `f(i)` for every `i in 0..n` across the pool. Blocks until all
    /// tasks finished. Inline when nested or trivially small.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: &F) {
        if n == 0 {
            return;
        }
        if n == 1 || self.workers.is_empty() || Self::in_worker() {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let batch_guard = lock(&self.batch_lock);

        // Contiguous per-queue slices (workers + the submitting thread).
        let n_queues = self.workers.len() + 1;
        let mut queues: Vec<Mutex<VecDeque<usize>>> = Vec::with_capacity(n_queues);
        let per = n / n_queues;
        let extra = n % n_queues;
        let mut next = 0usize;
        for q in 0..n_queues {
            let take = per + usize::from(q < extra);
            queues.push(Mutex::new((next..next + take).collect()));
            next += take;
        }
        debug_assert_eq!(next, n);

        // SAFETY: `run` blocks below until `remaining == 0`, so the
        // borrow of `f` outlives every task execution.
        let task: &(dyn Fn(usize) + Sync) = f;
        let task: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&_, &'static _>(task) };
        let handles = BatchHandles {
            queues: Arc::new(queues),
            task: RawTask(task),
            remaining: Arc::new(AtomicUsize::new(n)),
            panicked: Arc::new(AtomicBool::new(false)),
        };
        // Telemetry (observational only — never read back into
        // scheduling): the depth the queues start this batch at.
        POOL_BATCHES_TOTAL.inc();
        POOL_QUEUE_DEPTH.set(n as u64);

        let epoch = {
            let mut st = lock(&self.shared.state);
            st.epoch += 1;
            st.batch = Some(handles.clone());
            let e = st.epoch;
            drop(st);
            self.shared.work_ready.notify_all();
            e
        };

        // Participate with the last queue index. Mark this thread as a
        // worker for the duration: a task that itself calls `run`/`map`
        // (nested parallelism) must take the inline path rather than
        // re-locking `batch_lock` on this same thread.
        let was_worker = IN_POOL_WORKER.with(|f| f.replace(true));
        work_on(&self.shared, &handles, self.workers.len(), epoch);
        IN_POOL_WORKER.with(|f| f.set(was_worker));

        // Wait for in-flight tasks on other workers.
        let mut st = lock(&self.shared.state);
        while st.epoch == epoch && st.batch.is_some() {
            st = wait(&self.shared.batch_done, st);
        }
        drop(st);
        drop(batch_guard);

        if handles.panicked.load(Ordering::Acquire) {
            panic!("a task submitted to the thread pool panicked");
        }
    }

    /// Parallel map: `out[i] = f(i)`, order-stable and independent of the
    /// thread count / steal schedule.
    pub fn map<T: Send, F: Fn(usize) -> T + Sync>(&self, n: usize, f: F) -> Vec<T> {
        let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
        out.resize_with(n, MaybeUninit::uninit);
        let written: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();

        // If a task panics, `run` re-raises on this thread *after* the
        // batch has fully drained (no writer is in flight), and `out`
        // would otherwise drop as uninitialised memory, leaking every
        // completed T. The guard drops exactly the slots whose write
        // completed.
        struct DropInitialised<'a, T> {
            slots: *mut MaybeUninit<T>,
            written: &'a [AtomicBool],
            disarmed: bool,
        }
        impl<T> Drop for DropInitialised<'_, T> {
            fn drop(&mut self) {
                if self.disarmed {
                    return;
                }
                for (i, flag) in self.written.iter().enumerate() {
                    if flag.load(Ordering::Acquire) {
                        // SAFETY: the flag is set (Release) only after the
                        // slot's write completed, and no task is running.
                        unsafe { (*self.slots.add(i)).assume_init_drop() };
                    }
                }
            }
        }
        let mut guard =
            DropInitialised { slots: out.as_mut_ptr(), written: &written, disarmed: false };

        let slots = SendPtr(out.as_mut_ptr());
        self.run(n, &|i| {
            let v = f(i);
            // SAFETY: each index is executed exactly once, and distinct
            // indices write distinct slots.
            unsafe { (*slots.get().add(i)).write(v) };
            written[i].store(true, Ordering::Release);
        });
        guard.disarmed = true;

        // SAFETY: every slot was initialised by the batch (run() panics
        // — after draining — if any task panicked, so reaching here means
        // all n writes happened).
        let ptr = out.as_mut_ptr() as *mut T;
        let (len, cap) = (out.len(), out.capacity());
        std::mem::forget(out);
        unsafe { Vec::from_raw_parts(ptr, len, cap) }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    fn get(&self) -> *mut T {
        self.0
    }
}

fn worker_loop(shared: &Shared, me: usize) {
    loop {
        let (handles, epoch) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(b) = &st.batch {
                    break (b.clone(), st.epoch);
                }
                st = wait(&shared.work_ready, st);
            }
        };
        work_on(shared, &handles, me, epoch);
        // Queues drained; in-flight tasks may still run elsewhere. Sleep
        // until this batch is fully retired or a new one arrives.
        let mut st = lock(&shared.state);
        while !st.shutdown && st.epoch == epoch && st.batch.is_some() {
            st = wait(&shared.work_ready, st);
        }
    }
}

/// Execute tasks from queue `me`, stealing when empty, until the batch
/// has no queued work left.
fn work_on(shared: &Shared, handles: &BatchHandles, me: usize, epoch: u64) {
    while let Some(i) = pop_task(&handles.queues, me) {
        let task = handles.task;
        POOL_JOBS_TOTAL.inc();
        let t0 = if timing_enabled() { Some(std::time::Instant::now()) } else { None };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (task.0)(i)));
        if let Some(t0) = t0 {
            let ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
            POOL_JOB_NS.observe(ns);
            POOL_WORKER_BUSY_NS[me.min(MAX_WORKER_SLOTS - 1)].add(ns);
        }
        if res.is_err() {
            handles.panicked.store(true, Ordering::Release);
        }
        if handles.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last task of the batch: retire it and wake everyone.
            let mut st = lock(&shared.state);
            if st.epoch == epoch {
                st.batch = None;
            }
            drop(st);
            shared.batch_done.notify_all();
            shared.work_ready.notify_all();
        }
    }
}

/// Pop from our own deque front; steal the back half of a victim when
/// empty. Returns `None` when no queued work remains anywhere.
fn pop_task(queues: &[Mutex<VecDeque<usize>>], me: usize) -> Option<usize> {
    if let Some(i) = lock(&queues[me]).pop_front() {
        return Some(i);
    }
    let n = queues.len();
    for k in 1..n {
        let victim = (me + k) % n;
        let mut stolen = {
            let mut q = lock(&queues[victim]);
            let len = q.len();
            if len == 0 {
                continue;
            }
            q.split_off(len - (len + 1) / 2)
        };
        let first = stolen.pop_front();
        if !stolen.is_empty() {
            let mut mine = lock(&queues[me]);
            mine.extend(stolen);
        }
        if first.is_some() {
            POOL_STEALS_TOTAL.inc();
            return first;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_produces_ordered_results() {
        let pool = ThreadPool::new(4);
        let out = pool.map(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_runs_every_index_exactly_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        pool.run(500, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn results_independent_of_worker_count() {
        let a = ThreadPool::new(1).map(257, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let b = ThreadPool::new(7).map(257, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        assert_eq!(a, b);
    }

    #[test]
    fn ragged_tasks_load_balance_via_stealing() {
        // Front-loaded heavy tasks land in one queue; stealing must keep
        // the batch finishing (and correct) regardless.
        let pool = ThreadPool::new(4);
        let out = pool.map(64, |i| {
            if i < 8 {
                // Busy work.
                let mut x = 1u64;
                for k in 0..50_000 {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(k);
                }
                std::hint::black_box(x);
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn nested_map_degrades_to_inline() {
        let pool = ThreadPool::global();
        let out = pool.map(16, |i| {
            // Nested call from a worker (or the submitter) must not
            // deadlock; it runs inline.
            let inner = ThreadPool::global().map(8, |j| i * 100 + j);
            inner.iter().sum::<usize>()
        });
        assert_eq!(out[3], (0..8).map(|j| 300 + j).sum::<usize>());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = ThreadPool::new(2);
        for round in 0..50 {
            let out = pool.map(40, |i| i + round);
            assert_eq!(out[39], 39 + round);
        }
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                if i == 17 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // And the pool still works afterwards.
        assert_eq!(pool.map(10, |i| i).len(), 10);
    }

    #[test]
    fn map_panic_drops_completed_results() {
        static LIVE: AtomicU64 = AtomicU64::new(0);
        struct Counted;
        impl Counted {
            fn new() -> Self {
                LIVE.fetch_add(1, Ordering::Relaxed);
                Counted
            }
        }
        impl Drop for Counted {
            fn drop(&mut self) {
                LIVE.fetch_sub(1, Ordering::Relaxed);
            }
        }
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(64, |i| {
                if i == 40 {
                    panic!("boom");
                }
                Counted::new()
            })
        }));
        assert!(res.is_err());
        assert_eq!(LIVE.load(Ordering::Relaxed), 0, "completed results leaked");
    }

    #[test]
    fn zero_and_one_sized_batches() {
        let pool = ThreadPool::new(2);
        assert!(pool.map(0, |i| i).is_empty());
        assert_eq!(pool.map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn concurrent_submitters_serialise_safely() {
        let pool = ThreadPool::global();
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for t in 0..4 {
                joins.push(s.spawn(move || {
                    let out = pool.map(200, move |i| i as u64 + t);
                    out.iter().sum::<u64>()
                }));
            }
            for (t, j) in joins.into_iter().enumerate() {
                let expect: u64 = (0..200u64).map(|i| i + t as u64).sum();
                assert_eq!(j.join().unwrap(), expect);
            }
        });
    }
}
