//! Timing harness for the `harness = false` benches.
//!
//! `criterion` is not in the offline vendor set; this provides the part we
//! rely on: warmup, N timed iterations, median/p10/p90 and throughput
//! reporting, plus an optional JSON dump (consumed by EXPERIMENTS.md
//! tooling). Results print in a stable, grep-friendly format:
//!
//! ```text
//! bench fig1_rho_sweep/series_200pts        median=1.234ms p10=1.2ms p90=1.3ms iters=50
//! ```

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::percentile;

/// Re-export of `std::hint::black_box` so benches depend only on this mod.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
    /// Optional units-processed per iteration for throughput reporting.
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }

    pub fn p10(&self) -> f64 {
        percentile(&self.samples, 0.1)
    }

    pub fn p90(&self) -> f64 {
        percentile(&self.samples, 0.9)
    }

    pub fn report_line(&self) -> String {
        let mut line = format!(
            "bench {:<44} median={} p10={} p90={} iters={}",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.p10()),
            fmt_dur(self.p90()),
            self.iters
        );
        if let Some(u) = self.units_per_iter {
            line.push_str(&format!(" thrpt={}/s", fmt_count(u / self.median())));
        }
        line
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("median_s", Json::Num(self.median())),
            ("p10_s", Json::Num(self.p10())),
            ("p90_s", Json::Num(self.p90())),
            (
                "throughput_per_s",
                match self.units_per_iter {
                    Some(u) => Json::Num(u / self.median()),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Bench runner: collects measurements, prints a report, optionally dumps
/// JSON to `target/bench-results/<name>.json`.
pub struct Bench {
    suite: String,
    measurements: Vec<Measurement>,
    /// Target time per benchmark (split across iterations).
    target: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        let quick = std::env::var("CKPT_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            measurements: Vec::new(),
            target: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            min_iters: if quick { 3 } else { 10 },
            max_iters: if quick { 20 } else { 1000 },
        }
    }

    /// Time `f`, auto-choosing the iteration count to fill the target
    /// duration. `f` should return something `black_box`-able.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        self.run_with_units(name, None, &mut f)
    }

    /// Like [`Bench::run`], with a units-per-iteration for throughput.
    pub fn run_units<T>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> &Measurement {
        self.run_with_units(name, Some(units_per_iter), &mut f)
    }

    fn run_with_units<T>(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Measurement {
        // Warmup + calibration: one untimed call, then estimate rate.
        let t0 = Instant::now();
        bb(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target.as_secs_f64() / once) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            bb(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), iters, samples, units_per_iter };
        println!("{}", m.report_line());
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// Print the suite footer and write JSON results.
    pub fn finish(self) {
        println!("suite {} done: {} benchmarks", self.suite, self.measurements.len());
        let dir = std::path::Path::new("target/bench-results");
        if std::fs::create_dir_all(dir).is_ok() {
            let doc = Json::obj(vec![
                ("suite", Json::Str(self.suite.clone())),
                (
                    "benchmarks",
                    Json::Arr(self.measurements.iter().map(|m| m.to_json()).collect()),
                ),
            ]);
            let path = dir.join(format!("{}.json", self.suite));
            let _ = std::fs::write(path, doc.to_string_pretty());
        }
    }
}

/// Format a duration (seconds) with an adaptive unit.
pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Format a count with an adaptive suffix.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(2.5), "2.500s");
        assert_eq!(fmt_dur(2.5e-3), "2.500ms");
        assert_eq!(fmt_dur(2.5e-6), "2.500us");
        assert_eq!(fmt_dur(2.5e-9), "2.5ns");
    }

    #[test]
    fn fmt_count_units() {
        assert_eq!(fmt_count(5.0), "5.0");
        assert_eq!(fmt_count(5e3), "5.00k");
        assert_eq!(fmt_count(5e6), "5.00M");
        assert_eq!(fmt_count(5e9), "5.00G");
    }

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            name: "t".into(),
            iters: 3,
            samples: vec![0.001, 0.002, 0.003],
            units_per_iter: Some(100.0),
        };
        assert!((m.median() - 0.002).abs() < 1e-12);
        assert!(m.report_line().contains("thrpt="));
        let j = m.to_json();
        assert_eq!(j.req_f64("median_s").unwrap(), 0.002);
    }

    #[test]
    fn bench_runs_quickly_in_quick_mode() {
        std::env::set_var("CKPT_BENCH_QUICK", "1");
        let mut b = Bench::new("unit-test-suite");
        let m = b.run("noop", || 1 + 1);
        assert!(m.iters >= 3);
        b.finish();
        std::env::remove_var("CKPT_BENCH_QUICK");
    }
}
