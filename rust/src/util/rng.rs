//! Seedable PCG-XSH-RR 64/32 pseudo-random generator plus the
//! distributions the simulator and workload need.
//!
//! PCG (O'Neill 2014) is small, fast, statistically solid, and — crucially
//! for reproducibility of every experiment in EXPERIMENTS.md —
//! deterministic across platforms. All stochastic components of the crate
//! (failure injection, Monte-Carlo simulation, synthetic data) take an
//! explicit seed and derive independent streams via [`Pcg64::split`].

/// PCG-XSH-RR with 64-bit state and 32-bit output, wrapped to produce
/// 64-bit values by concatenating two outputs.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids give statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator (new stream keyed by `tag`).
    /// Used to give each simulated node / worker its own failure stream.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg64::new(seed, tag.wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free for the
    /// ranges we use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply rejection sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Exponential with mean `mean` (inverse-CDF). This is the paper's
    /// failure inter-arrival model: MTBF `μ` ⇒ `Exp(1/μ)`.
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        // 1 - uniform() ∈ (0, 1] avoids ln(0).
        -mean * (1.0 - self.uniform()).ln()
    }

    /// Weibull with shape `k` and scale `lambda` (inverse-CDF). Used by
    /// the simulator's non-exponential failure extension: `k < 1` models
    /// infant mortality observed on real HPC failure logs.
    #[inline]
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        scale * (-(1.0 - self.uniform()).ln()).powf(1.0 / shape)
    }

    /// Standard normal via Box–Muller (one value per call; we do not
    /// cache the second — simplicity over speed, this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)` — synthetic data.
    pub fn fill_uniform(&mut self, xs: &mut [f32], lo: f32, hi: f32) {
        for x in xs.iter_mut() {
            *x = lo + (hi - lo) * self.uniform() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent_of_parent_continuation() {
        let mut parent = Pcg64::seeded(9);
        let mut child = parent.split(3);
        let c: Vec<u64> = (0..16).map(|_| child.next_u64()).collect();
        let p: Vec<u64> = (0..16).map(|_| parent.next_u64()).collect();
        assert_ne!(c, p);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg64::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean_matches() {
        let mut rng = Pcg64::seeded(6);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(3.5)).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn exponential_memoryless_tail() {
        // P(X > t) = exp(-t/mean): check at t = mean (should be ~0.3679).
        let mut rng = Pcg64::seeded(7);
        let n = 200_000;
        let tail = (0..n).filter(|_| rng.exponential(2.0) > 2.0).count() as f64 / n as f64;
        assert!((tail - (-1.0f64).exp()).abs() < 0.01, "tail={tail}");
    }

    #[test]
    fn weibull_shape1_is_exponential() {
        let mut rng = Pcg64::seeded(8);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.weibull(1.0, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn weibull_mean_gamma_relation() {
        // shape=2 ⇒ mean = scale * Γ(1.5) = scale * sqrt(pi)/2.
        let mut rng = Pcg64::seeded(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.weibull(2.0, 1.0)).sum::<f64>() / n as f64;
        let expect = std::f64::consts::PI.sqrt() / 2.0;
        assert!((mean - expect).abs() < 0.01, "mean={mean} expect={expect}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(10);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_uniform_in_range() {
        let mut rng = Pcg64::seeded(12);
        let mut buf = vec![0f32; 1000];
        rng.fill_uniform(&mut buf, -0.5, 0.5);
        assert!(buf.iter().all(|&x| (-0.5..0.5).contains(&x)));
        assert!(buf.iter().any(|&x| x != 0.0));
    }
}
