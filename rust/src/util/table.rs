//! ASCII table printer used by the examples and the CLI to render the
//! paper's tables/series in a terminal, and a small CSV writer used by the
//! figure harness (one CSV per figure so the plots can be regenerated with
//! any plotting tool).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// An in-memory table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            align: header.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override alignments (defaults to all right-aligned).
    pub fn with_align(mut self, align: &[Align]) -> Self {
        assert_eq!(align.len(), self.header.len());
        self.align = align.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from Display values.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            out.push('+');
            for w in &width {
                for _ in 0..w + 2 {
                    out.push('-');
                }
                out.push('+');
            }
            out.push('\n');
        };
        let line = |out: &mut String, cells: &[String], align: &[Align]| {
            out.push('|');
            for ((c, w), a) in cells.iter().zip(&width).zip(align) {
                let pad = w - c.chars().count();
                match a {
                    Align::Left => {
                        let _ = write!(out, " {}{} ", c, " ".repeat(pad));
                    }
                    Align::Right => {
                        let _ = write!(out, " {}{} ", " ".repeat(pad), c);
                    }
                }
                out.push('|');
            }
            out.push('\n');
        };
        sep(&mut out);
        line(&mut out, &self.header, &self.align);
        sep(&mut out);
        for row in &self.rows {
            line(&mut out, row, &self.align);
        }
        sep(&mut out);
        out
    }

    /// Write the table as CSV (header + rows, RFC-4180 quoting).
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        s.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        std::fs::write(path, s)
    }
}

/// Format a float with `prec` significant-looking decimals, trimming noise.
pub fn fnum(x: f64, prec: usize) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).with_align(&[Align::Left, Align::Right]);
        t.row(&["alpha".into(), "1.0".into()]);
        t.row(&["b".into(), "12345.6".into()]);
        let s = t.render();
        assert!(s.contains("| alpha |"));
        assert!(s.contains("| 12345.6 |"));
        // All lines same width.
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_quoting() {
        let dir = std::env::temp_dir().join("ckpt_table_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(&["a", "b,c"]);
        t.row(&["x\"y".into(), "1".into()]);
        t.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,\"b,c\"\n\"x\"\"y\",1\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 3), "1.235");
        assert_eq!(fnum(f64::INFINITY, 2), "inf");
    }
}
