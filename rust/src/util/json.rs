//! Minimal JSON value type, writer and recursive-descent parser.
//!
//! No `serde` exists in the offline vendor set, so this module carries the
//! crate's interchange needs: scenario configs, artifact metadata written
//! by `python/compile/aot.py`, and machine-readable experiment results
//! (`figures` emits one JSON file per figure next to the CSV).
//!
//! Supported: the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers parse as `f64` (adequate: every value we
//! exchange is a float, small int, or string).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch a required f64 field from an object, with a useful error.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError::Schema(format!("missing/invalid number field `{key}`")))
    }

    /// Fetch a required string field from an object.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::Schema(format!("missing/invalid string field `{key}`")))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossiness).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    Parse(usize, String),
    Schema(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => write!(f, "json parse error at byte {at}: {msg}"),
            JsonError::Schema(msg) => write!(f, "json schema error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(JsonError::Parse(p.pos, "trailing characters".into()));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.pos, msg.to_string()))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{word}`"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).or_else(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| {
                                        JsonError::Parse(self.pos, "bad \\u escape".into())
                                    })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.pos, "bad \\u hex".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("invalid escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    if rest.len() < ch_len {
                        return self.err("truncated utf-8");
                    }
                    let ch = std::str::from_utf8(&rest[..ch_len])
                        .map_err(|_| JsonError::Parse(self.pos, "invalid utf-8".into()))?;
                    s.push_str(ch);
                    self.pos += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string_compact();
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&compact).unwrap(), v);
        assert_eq!(parse(&pretty).unwrap(), v);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_errors_have_position() {
        match parse("[1, ") {
            Err(JsonError::Parse(pos, _)) => assert!(pos >= 3),
            other => panic!("expected parse error, got {other:?}"),
        }
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1] extra").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\n");
        let s = Json::Str("x\"\\\n\t\u{1}".into()).to_string_compact();
        assert_eq!(parse(&s).unwrap().as_str().unwrap(), "x\"\\\n\t\u{1}");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(parse("-0.125").unwrap().as_f64().unwrap(), -0.125);
        assert_eq!(parse("1e-3").unwrap().as_f64().unwrap(), 1e-3);
        // Integral floats print without decimal point.
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
        // NaN becomes null.
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn helpers() {
        let v = Json::obj(vec![("x", Json::Num(1.0)), ("s", Json::Str("y".into()))]);
        assert_eq!(v.req_f64("x").unwrap(), 1.0);
        assert_eq!(v.req_str("s").unwrap(), "y");
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_str("x").is_err());
        assert_eq!(Json::arr_f64(&[1.0, 2.0]).as_arr().unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }
}
