//! Phase-based energy accounting for *real* runs (§2.2 applied to the
//! coordinator).
//!
//! No power meter exists on this machine, so — exactly like the paper —
//! energy is `Σ phase_time × phase_power` with the phase powers taken
//! from the scenario's [`crate::model::params::PowerParams`]:
//!
//! | phase      | power                        |
//! |------------|------------------------------|
//! | Compute    | `P_Static + P_Cal`           |
//! | Checkpoint | `P_Static + ω·P_Cal + P_IO`  |
//! | Recovery   | `P_Static + P_IO`            |
//! | Down       | `P_Static + P_Down`          |
//! | Idle       | `P_Static`                   |
//!
//! (ω enters because a non-blocking checkpoint keeps the CPU doing useful
//! work at rate ω while the I/O system writes — same convention as the
//! simulator and the analytical `T_Cal`.)

use crate::model::params::PowerParams;

/// The coordinator's power states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Compute,
    Checkpoint,
    Recovery,
    Down,
    Idle,
}

pub const ALL_PHASES: [Phase; 5] =
    [Phase::Compute, Phase::Checkpoint, Phase::Recovery, Phase::Down, Phase::Idle];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Checkpoint => "checkpoint",
            Phase::Recovery => "recovery",
            Phase::Down => "down",
            Phase::Idle => "idle",
        }
    }

    fn index(&self) -> usize {
        match self {
            Phase::Compute => 0,
            Phase::Checkpoint => 1,
            Phase::Recovery => 2,
            Phase::Down => 3,
            Phase::Idle => 4,
        }
    }
}

/// Accumulates wall time per phase.
#[derive(Debug, Clone, Default)]
pub struct PhaseTracker {
    seconds: [f64; 5],
}

impl PhaseTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, seconds: f64) {
        debug_assert!(seconds >= 0.0, "negative phase duration {seconds}");
        self.seconds[phase.index()] += seconds;
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.seconds[phase.index()]
    }

    pub fn total(&self) -> f64 {
        self.seconds.iter().sum()
    }

    /// Merge another tracker (e.g. a worker thread's) into this one.
    pub fn merge(&mut self, other: &PhaseTracker) {
        for i in 0..self.seconds.len() {
            self.seconds[i] += other.seconds[i];
        }
    }
}

/// Energy breakdown of a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    pub static_e: f64,
    pub cal_e: f64,
    pub io_e: f64,
    pub down_e: f64,
    pub total: f64,
}

/// Apply the paper's power model to measured phase times.
/// `omega` is the effective compute rate during checkpoints.
pub fn energy_of(tracker: &PhaseTracker, power: &PowerParams, omega: f64) -> EnergyBreakdown {
    let compute = tracker.get(Phase::Compute);
    let ckpt = tracker.get(Phase::Checkpoint);
    let rec = tracker.get(Phase::Recovery);
    let down = tracker.get(Phase::Down);

    let static_e = power.p_static * tracker.total();
    let cal_e = power.p_cal * (compute + omega * ckpt);
    let io_e = power.p_io * (ckpt + rec);
    let down_e = power.p_down * down;
    EnergyBreakdown { static_e, cal_e, io_e, down_e, total: static_e + cal_e + io_e + down_e }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn power() -> PowerParams {
        PowerParams::new(10.0, 10.0, 100.0, 5.0).unwrap()
    }

    #[test]
    fn accumulates_and_totals() {
        let mut t = PhaseTracker::new();
        t.add(Phase::Compute, 10.0);
        t.add(Phase::Compute, 5.0);
        t.add(Phase::Checkpoint, 2.0);
        assert_eq!(t.get(Phase::Compute), 15.0);
        assert_eq!(t.total(), 17.0);
        assert_eq!(t.get(Phase::Idle), 0.0);
    }

    #[test]
    fn merge_adds_componentwise() {
        let mut a = PhaseTracker::new();
        a.add(Phase::Down, 1.0);
        let mut b = PhaseTracker::new();
        b.add(Phase::Down, 2.0);
        b.add(Phase::Recovery, 3.0);
        a.merge(&b);
        assert_eq!(a.get(Phase::Down), 3.0);
        assert_eq!(a.get(Phase::Recovery), 3.0);
    }

    #[test]
    fn energy_formula_blocking() {
        let mut t = PhaseTracker::new();
        t.add(Phase::Compute, 100.0);
        t.add(Phase::Checkpoint, 10.0);
        t.add(Phase::Recovery, 4.0);
        t.add(Phase::Down, 2.0);
        let e = energy_of(&t, &power(), 0.0);
        assert_eq!(e.static_e, 10.0 * 116.0);
        assert_eq!(e.cal_e, 10.0 * 100.0);
        assert_eq!(e.io_e, 100.0 * 14.0);
        assert_eq!(e.down_e, 5.0 * 2.0);
        assert_eq!(e.total, e.static_e + e.cal_e + e.io_e + e.down_e);
    }

    #[test]
    fn omega_credits_checkpoint_cpu() {
        let mut t = PhaseTracker::new();
        t.add(Phase::Compute, 100.0);
        t.add(Phase::Checkpoint, 10.0);
        let blocking = energy_of(&t, &power(), 0.0);
        let overlapped = energy_of(&t, &power(), 1.0);
        assert_eq!(overlapped.cal_e - blocking.cal_e, 10.0 * 10.0);
    }

    #[test]
    fn phase_names_unique() {
        let names: std::collections::BTreeSet<_> =
            ALL_PHASES.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), ALL_PHASES.len());
    }
}
